"""Multi-accelerator GEMM (the paper's Tesla S2050 section) on 8
forced-host devices: ring / column / row schedules, with weak-scaling
sanity and the ICI-byte model.

    PYTHONPATH=src python examples/distributed_gemm.py
(re-execs itself with XLA_FLAGS to get 8 devices)
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, "src")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed import comm_model_bytes, sharded_matmul  # noqa: E402
from repro.launch.mesh import axis_kw  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("model",), **axis_kw(1))
    rng = np.random.default_rng(0)
    m = k = n = 1024
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    ref = a @ b

    print(f"devices: {len(jax.devices())}, GEMM {m}x{k}x{n}")
    for sched in ("column", "row", "ring"):
        f = jax.jit(lambda x, y, s=sched: sharded_matmul(x, y, mesh,
                                                         schedule=s))
        out = f(a, b)
        err = float(jnp.max(jnp.abs(out - ref)))
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(f(a, b))
        t = (time.perf_counter() - t0) / 3
        comm = comm_model_bytes(m, n, k, 8, 4, sched)
        print(f"  {sched:8s} {t*1e3:7.1f}ms  max|err|={err:.2e}  "
              f"model ICI bytes/dev={comm/1e6:.1f}MB")
    print("ring schedule overlaps collective-permute with local dots "
          "(see HLO); the paper's 'matrices must be very large' remark "
          "is the comm column above vs the n^3 compute.")


if __name__ == "__main__":
    main()
