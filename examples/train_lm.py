"""End-to-end driver (deliverable b): train a ~100M-parameter decoder LM
for a few hundred steps on this host, with checkpointing, failure
injection and resume — the same launcher code path the multi-pod mesh
uses.

    PYTHONPATH=src python examples/train_lm.py \
        [--steps 300] [--batch 4] [--seq 256] [--small]

--small swaps in a ~2M model for a fast smoke run.
"""

import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig  # noqa: E402
from repro.launch import train as train_launcher  # noqa: E402
import repro.configs as C  # noqa: E402


# ~100M-parameter config (qwen3-family block structure)
LM_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=50_304,
    qk_norm=True,
    dtype="float32",           # CPU: f32 compute is faster than bf16 emu
    remat="none",
    attn_chunk=512,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="ckpts/train_lm")
    ap.add_argument("--fail-at", type=int, nargs="*", default=())
    args = ap.parse_args()

    cfg = LM_100M
    if args.small:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                                  n_kv_heads=2, d_ff=512, vocab=4096,
                                  name="lm-2m")

    # register on the fly so the standard launcher can drive it
    import repro.configs as configs
    configs._REGISTRY[cfg.name] = cfg

    argv = ["--arch", cfg.name, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--lr", "3e-4", "--warmup", "30",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--log-every", "10"]
    for s in args.fail_at:
        argv += ["--fail-at", str(s)]
    losses = train_launcher.main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print("train_lm done")


if __name__ == "__main__":
    main()
