"""Quickstart: the paper's kernel, the typed execution Policy, and a
tiny end-to-end model — in ~60 lines, entirely on the public facade.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW
from repro.training import train_loop as TL

# ----------------------------------------------------------------- 1.
# The paper's tiled GEMM (Listing 4 -> Pallas/VMEM), selected by Policy.
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
b = jnp.asarray(rng.normal(size=(512, 384)), jnp.float32)

pallas = repro.Policy(backend="pallas")   # interpret=None: auto off-TPU
c_ref = repro.matmul(a, b)                # ambient default: plain XLA
c_pal = repro.matmul(a, b, policy=pallas)
print("tiled Pallas GEMM max|err| vs XLA:",
      float(jnp.max(jnp.abs(c_pal - c_ref))))
print("policy:", pallas.fingerprint() or "xla-default",
      "-> kernel", pallas.kernel_fingerprint)

# The same selection as an ambient scope — no per-call plumbing:
with pallas.scope():
    h = repro.gated_mlp(a, b[:, :256], b[:, 128:384])   # dual-GEMM SwiGLU
print("gated_mlp under scope:", h.shape)

# ----------------------------------------------------------------- 2.
# The paper's dtype study in one call: complex GEMM through real kernels.
ac = jnp.asarray(rng.normal(size=(64, 64)) + 1j * rng.normal(size=(64, 64)),
                 jnp.complex64)
cc = repro.matmul(ac, ac, policy=pallas)   # gauss3 decomposition
print("complex64 via 3 real GEMMs max|err|:",
      float(jnp.max(jnp.abs(cc - ac @ ac))))

# ----------------------------------------------------------------- 3.
# A model whose every dense op routes through that chokepoint.
cfg = repro.get_config("qwen3-0.6b", reduced=True)
opt = AdamW(lr=1e-3)
state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
step = jax.jit(TL.make_train_step(cfg, opt), donate_argnums=(0,))
data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=4)
for i in range(10):
    state, metrics = step(state, jax.tree.map(jnp.asarray, data.batch_at(i)))
    if i % 3 == 0:
        print(f"step {i} loss {float(metrics['loss']):.4f}")
print("quickstart OK")
