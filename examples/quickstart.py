"""Quickstart: the paper's kernel, the GEMM chokepoint, and a tiny
end-to-end model — in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import blocking, gemm
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW
from repro.training import train_loop as TL

# ----------------------------------------------------------------- 1.
# The paper's tiled GEMM (Listing 4 -> Pallas/VMEM), via the chokepoint.
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
b = jnp.asarray(rng.normal(size=(512, 384)), jnp.float32)

c_ref = gemm.matmul(a, b, backend="xla")
c_pal = gemm.matmul(a, b, backend="pallas_interpret")   # the TPU kernel
print("tiled Pallas GEMM max|err| vs XLA:",
      float(jnp.max(jnp.abs(c_pal - c_ref))))

cfgb = blocking.choose_block_config(4096, 4096, 4096, 2)
print(f"VMEM tile choice for 4096^3 bf16: {cfgb.bm}x{cfgb.bn}x{cfgb.bk} "
      f"({cfgb.vmem_bytes(2)/2**20:.1f} MiB of 128 MiB)")

# ----------------------------------------------------------------- 2.
# The paper's dtype study in one call: complex GEMM through real kernels.
ac = jnp.asarray(rng.normal(size=(64, 64)) + 1j * rng.normal(size=(64, 64)),
                 jnp.complex64)
cc = gemm.matmul(ac, ac, backend="pallas_interpret")    # gauss3 decomposition
print("complex64 via 3 real GEMMs max|err|:",
      float(jnp.max(jnp.abs(cc - ac @ ac))))

# ----------------------------------------------------------------- 3.
# A model whose every dense op routes through that chokepoint.
cfg = C.get_config("qwen3-0.6b", reduced=True)
opt = AdamW(lr=1e-3)
state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
step = jax.jit(TL.make_train_step(cfg, opt), donate_argnums=(0,))
data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=4)
for i in range(10):
    state, metrics = step(state, jax.tree.map(jnp.asarray, data.batch_at(i)))
    if i % 3 == 0:
        print(f"step {i} loss {float(metrics['loss']):.4f}")
print("quickstart OK")
