"""Table 2 reproduction driver: the paper's 4096x4096 GEMM across
dtypes and kernel generations, measured where the container allows and
modeled (per-chip roofline) where it doesn't — printed side by side
with the paper's own seconds.

    PYTHONPATH=src python examples/paper_reproduction.py [--n 1024]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gemm import CONFIG as PAPER
from repro.core.policy import Policy
from repro.core import blocking, gemm, hw


def wall(f, *args, iters=3):
    jax.block_until_ready(f(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048,
                    help="measured size (paper's 4096 is modeled)")
    args = ap.parse_args()
    n = args.n
    rng = np.random.default_rng(0)

    print(f"== measured on this host (XLA CPU), n={n} ==")
    for dtype in ("float32", "complex64"):
        if dtype == "complex64":
            a = jnp.asarray(rng.normal(size=(n, n))
                            + 1j * rng.normal(size=(n, n)), dtype)
        else:
            a = jnp.asarray(rng.normal(size=(n, n)), dtype)
        f = jax.jit(lambda x: gemm.matmul(x, x, policy=Policy()))
        t = wall(f, a)
        print(f"  {dtype:10s} {t:8.3f}s")

    print(f"\n== modeled, paper's n={PAPER.n}, float32 ==")
    print(f"{'config':26s}{'model s':>10s}{'paper s':>10s}")
    rows = [
        ("tesla-c1060 (shared)", hw.TESLA_C1060, True,
         PAPER.reference_times[("tesla-c1060", "float32")]),
        ("tesla-c2050 naive", hw.TESLA_C2050, False,
         PAPER.reference_times[("tesla-c2050", "float32")]),
        ("tesla-c2050 shared", hw.TESLA_C2050, True,
         PAPER.reference_times[("tesla-c2050-shared", "float32")]),
    ]
    for name, chip, shared, ref in rows:
        cfgb = (blocking.choose_block_config(PAPER.n, PAPER.n, PAPER.n, 4,
                                             chip=chip) if shared else None)
        t = blocking.gemm_time_model(PAPER.n, PAPER.n, PAPER.n, 4, cfgb,
                                     chip=chip)["t_total"]
        print(f"{name:26s}{t:10.3f}{ref:10.2f}")
    v5e = blocking.gemm_time_model(
        PAPER.n, PAPER.n, PAPER.n, 2,
        blocking.choose_block_config(PAPER.n, PAPER.n, PAPER.n, 2),
        chip=hw.TPU_V5E)["t_total"]
    print(f"{'tpu-v5e shared (bf16)':26s}{v5e:10.4f}{'—':>10s}")

    print("\npaper's headline: shared-memory kernel ~3x over naive GPU, "
          ">1000x over 1-core CPU — both directions reproduced above "
          "(model vs paper columns; CPU wall-clock vs v5e model).")


if __name__ == "__main__":
    main()
