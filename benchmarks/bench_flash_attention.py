"""Flash-attention suite benchmark: fused fwd/bwd + decode kernel.

Four claims, each checkable on this CPU-only container:

  1. **Byte accounting (asserted).** From the same static traffic models
     as the Fig.-8 reproduction (core.blocking / roofline.analysis):
     the decode kernel moves >= 80% fewer modeled HBM bytes than the
     masked dense scan at an early-stream shape (pos=127 in a
     depth-4096 cache — the prefix skip is the win), and the
     recompute-style backward moves >= 50% fewer bytes than the
     stored-S formulation at a training shape (the four quadratic f32
     round trips are the loss). Modeled, so it holds in interpret mode
     and transfers to the TPU where it becomes wall-clock.
  2. **Decode parity (asserted).** The pallas decode kernel matches the
     chunked-XLA masked path to f32 roundoff on active slots, per-slot
     depths included (bitwise equality only holds when the two paths
     share one accumulation order — tests/test_serving.py pins
     token-level exactness engine-vs-reference under a single policy).
  3. **VJP parity (asserted).** Gradients through the fused
     flash_attention_bwd custom-VJP match jax.grad through the chunked
     reference composition (the path it replaced) to f32 tolerance.
  4. **Interpreter wall-clock (emitted).** Mechanism record only —
     interpret timings are not TPU-meaningful (EXPERIMENTS §Autotune).
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_flash_attention.py`
    import os
    import sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core.policy import Policy
from repro.kernels import ops
from repro.models.attention import attention, chunked_attention
from repro.roofline import analysis

_PI = Policy(backend="pallas", interpret=True)
_XLA = Policy(backend="xla")

# Byte-accounting shapes. Decode: a young stream in a long-max-length
# cache — the regime continuous batching actually serves — where the
# prefix skip dominates. Backward: a training shape where the (tq, tk)
# matrices dwarf the linear operands.
DECODE_POS, DECODE_TK, HEAD_D = 127, 4096, 64
BWD_TQ = BWD_TK = 2048
DECODE_FLOOR = 0.80
BWD_FLOOR = 0.50
FWD_FLOOR = 0.80

# Small shapes for the measured interpret-mode passes.
B, TQ, TK, H, HKV, D = 2, 256, 512, 4, 2, 32


def _byte_accounting() -> None:
    s = analysis.decode_attention_savings(DECODE_POS, DECODE_TK, HEAD_D, 2)
    emit(f"flash_decode_hbm_bytes_pos{DECODE_POS}_tk{DECODE_TK}", 0.0,
         f"fused_bytes={s['fused_bytes']};unfused_bytes={s['unfused_bytes']};"
         f"saved_frac={s['saved_frac']:.3f};floor={DECODE_FLOOR}")
    assert s["saved_frac"] >= DECODE_FLOOR, (
        f"decode kernel moves only {s['saved_frac']:.1%} fewer HBM bytes "
        f"at pos={DECODE_POS}, tk={DECODE_TK} (floor {DECODE_FLOOR:.0%})")
    # full cache: the skip win evaporates by design — emit for the record
    s_full = analysis.decode_attention_savings(
        DECODE_TK - 1, DECODE_TK, HEAD_D, 2)
    emit("flash_decode_hbm_bytes_full_cache", 0.0,
         f"saved_frac={s_full['saved_frac']:.3f}")

    s = analysis.attention_bwd_savings(BWD_TQ, BWD_TK, HEAD_D, 2)
    emit(f"flash_bwd_hbm_bytes_{BWD_TQ}x{BWD_TK}", 0.0,
         f"fused_bytes={s['fused_bytes']};unfused_bytes={s['unfused_bytes']};"
         f"saved_frac={s['saved_frac']:.3f};floor={BWD_FLOOR}")
    assert s["saved_frac"] >= BWD_FLOOR, (
        f"recompute bwd moves only {s['saved_frac']:.1%} fewer HBM bytes "
        f"than stored-S at {BWD_TQ}x{BWD_TK} (floor {BWD_FLOOR:.0%})")

    s = analysis.attention_fwd_savings(BWD_TQ, BWD_TK, HEAD_D, 2)
    emit(f"flash_fwd_hbm_bytes_{BWD_TQ}x{BWD_TK}", 0.0,
         f"saved_frac={s['saved_frac']:.3f};floor={FWD_FLOOR}")
    assert s["saved_frac"] >= FWD_FLOOR, (
        f"flash fwd moves only {s['saved_frac']:.1%} fewer HBM bytes "
        f"than materialised softmax (floor {FWD_FLOOR:.0%})")


def _decode_parity(rng) -> None:
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, TK, HKV, D)), jnp.float32)
    pos = jnp.asarray([TK - 1, 37], jnp.int32)       # ragged depths
    fused = ops.flash_decode(q, kv, kv, pos=pos, policy=_PI)
    ref = chunked_attention(q, kv, kv, causal=True, window=None,
                            chunk=128, q_offset=pos, kv_len=pos + 1)
    err = float(jnp.max(jnp.abs(fused - ref)))
    emit("flash_decode_parity", 0.0,
         f"bitwise_equal={bool(jnp.all(fused == ref))};"
         f"max_abs_err={err:.1e}")
    assert err <= 2e-6, \
        f"flash_decode diverged from the chunked masked path: {err}"


def _vjp_parity(rng) -> None:
    q = jnp.asarray(rng.normal(size=(B, TQ, H, D)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, TQ, HKV, D)), jnp.float32)

    def fused_loss(q_, k_, v_):
        return jnp.sum(attention(q_, k_, v_, causal=True, window=None,
                                 chunk=128, policy=_PI) ** 2)

    def ref_loss(q_, k_, v_):
        return jnp.sum(chunked_attention(q_, k_, v_, causal=True,
                                         window=None, chunk=128) ** 2)

    grads = jax.grad(fused_loss, argnums=(0, 1, 2))(q, kv, kv)
    refs = jax.grad(ref_loss, argnums=(0, 1, 2))(q, kv, kv)
    err = max(float(jnp.max(jnp.abs(gi - ri)))
              for gi, ri in zip(grads, refs))
    ref_scale = max(float(jnp.max(jnp.abs(ri))) for ri in refs)
    emit("flash_bwd_vjp_parity", 0.0,
         f"max_abs_err={err:.2e};ref_scale={ref_scale:.1e}")
    assert err <= 1e-3 * max(ref_scale, 1.0), \
        f"fused attention VJP diverged from the chunked reference: {err}"


def _interpret_timings(rng) -> None:
    q = jnp.asarray(rng.normal(size=(B, TQ, H, D)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, TQ, HKV, D)), jnp.float32)
    qd = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    cache = jnp.asarray(rng.normal(size=(B, TK, HKV, D)), jnp.float32)
    pos = jnp.full((B,), TK - 1, jnp.int32)

    t = time_jax(lambda x, y: ops.flash_attention(x, y, y, causal=True,
                                                  policy=_PI),
                 q, kv, warmup=1, iters=2)
    emit("flash_fwd_pallas_interpret", t, "streamed-KV")
    t = time_jax(lambda x, y, p: ops.flash_decode(x, y, y, pos=p,
                                                  policy=_PI),
                 qd, cache, pos, warmup=1, iters=2)
    emit("flash_decode_pallas_interpret", t,
         "interpreter-not-wallclock-meaningful")


def run() -> None:
    rng = np.random.default_rng(13)
    _byte_accounting()
    _decode_parity(rng)
    _vjp_parity(rng)
    _interpret_timings(rng)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    run()
    print(f"# wrote {write_bench_json(tag='flash_attention')}")
