"""Fused-epilogue / dual-GEMM SwiGLU benchmark.

Three claims, each checkable on this CPU-only container:

  1. **Byte accounting (asserted).** The fused SwiGLU path moves >= 40%
     fewer HBM bytes per MLP call than the unfused composition, by the
     same static traffic model the Fig.-8 reproduction uses
     (roofline.analysis.gated_mlp_savings — modeled, so it holds in
     interpret mode and transfers to the TPU where it becomes
     wall-clock).
  2. **Token-exact forward (asserted).** With matched tiles the fused
     dual-GEMM kernel is bit-identical in f32 to the unfused tiled
     composition: both run silu on the same f32 accumulator values.
  3. **VJP parity (asserted).** Gradients through the fused
     core.gemm.gated_mlp chokepoint match jax.grad of the plain jnp
     reference (the fused path trains).

Interpreter wall-clock is also emitted for the mechanism record
(interpret timings are not TPU-meaningful — EXPERIMENTS §Autotune).
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_fused_epilogue.py`
    import os
    import sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import blocking, gemm
from repro.core.policy import Policy
from repro.kernels import ops

_PI = Policy.from_backend("pallas_interpret")
from repro.roofline import analysis

# The byte-accounting assertion shape: skinny d_model vs wide d_ff makes
# the (M, d_ff) intermediates the dominant traffic term (MoE-expert-like
# geometry); bf16 itemsize matches the serving configs.
ASSERT_SHAPE = (2048, 512, 4096)            # (m, d_model, d_ff)
ASSERT_ITEMSIZE = 2
SAVINGS_FLOOR = 0.40

# Small shapes for the measured interpret-mode passes.
M, D, F = 128, 64, 256


def _byte_accounting() -> None:
    m, d, f = ASSERT_SHAPE
    s = analysis.gated_mlp_savings(m, d, f, ASSERT_ITEMSIZE)
    emit(f"fused_swiglu_hbm_bytes_{m}x{d}x{f}", 0.0,
         f"fused_bytes={s['fused_bytes']};unfused_bytes={s['unfused_bytes']};"
         f"saved_frac={s['saved_frac']:.3f};floor={SAVINGS_FLOOR}")
    assert s["saved_frac"] >= SAVINGS_FLOOR, (
        f"fused SwiGLU moves only {s['saved_frac']:.1%} fewer HBM bytes "
        f"at {ASSERT_SHAPE} (floor {SAVINGS_FLOOR:.0%})")
    # per-epilogue saving of the single-GEMM fused flush, same model
    for ep in ("bias", "bias_gelu", "bias_silu", "residual"):
        fused = analysis.epilogue_traffic_bytes(m, d, f, ASSERT_ITEMSIZE,
                                                ep, fused=True)
        unfused = analysis.epilogue_traffic_bytes(m, d, f, ASSERT_ITEMSIZE,
                                                  ep, fused=False)
        emit(f"fused_epilogue_hbm_bytes_{ep}", 0.0,
             f"saved_frac={1 - fused / unfused:.3f}")


def _token_exactness(rng) -> None:
    a = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    cfg = blocking.choose_block_config(M, F, D, 4, n_rhs=2)
    fused = ops.gated_matmul(a, wg, wu, policy=_PI,
                             block=cfg)
    g = ops.matmul(a, wg, policy=_PI, block=cfg)
    u = ops.matmul(a, wu, policy=_PI, block=cfg)
    unfused = jax.nn.silu(g) * u
    exact = bool(jnp.all(fused == unfused))
    emit("fused_swiglu_token_exact_f32", 0.0,
         f"bitwise_equal={exact};max_abs_err="
         f"{float(jnp.max(jnp.abs(fused - unfused))):.1e}")
    assert exact, "fused SwiGLU diverged from the unfused tiled composition"


def _vjp_parity(rng) -> None:
    a = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)

    def fused_loss(x, g_, u_):
        return jnp.sum(gemm.gated_mlp(
            x, g_, u_, policy=_PI) ** 2)

    def ref_loss(x, g_, u_):
        return jnp.sum((jax.nn.silu(x @ g_) * (x @ u_)) ** 2)

    grads = jax.grad(fused_loss, argnums=(0, 1, 2))(a, wg, wu)
    refs = jax.grad(ref_loss, argnums=(0, 1, 2))(a, wg, wu)
    err = max(float(jnp.max(jnp.abs(gi - ri)))
              for gi, ri in zip(grads, refs))
    scale = max(float(jnp.max(jnp.abs(ri))) for ri in refs)
    emit("fused_swiglu_vjp_parity", 0.0,
         f"max_abs_err={err:.2e};ref_scale={scale:.1e}")
    assert err <= 1e-3 * max(scale, 1.0), \
        f"fused VJP diverged from jax.grad of the reference: {err}"


def _interpret_timings(rng) -> None:
    a = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(F,)), jnp.float32)

    t = time_jax(lambda x: ops.gated_matmul(
        x, wg, wu, policy=_PI), a, warmup=1, iters=2)
    emit("gated_matmul_pallas_interpret", t, "1-kernel-pass")
    t = time_jax(
        lambda x: jax.nn.silu(
            ops.matmul(x, wg, policy=_PI))
        * ops.matmul(x, wu, policy=_PI),
        a, warmup=1, iters=2)
    emit("gated_matmul_unfused_interpret", t, "2-kernel-passes+ew")
    t = time_jax(lambda x: ops.matmul(
        x, wg, policy=_PI, epilogue="bias_gelu", bias=bias),
        a, warmup=1, iters=2)
    emit("matmul_bias_gelu_fused_interpret", t,
         "interpreter-not-wallclock-meaningful")


def run() -> None:
    rng = np.random.default_rng(7)
    _byte_accounting()
    _token_exactness(rng)
    _vjp_parity(rng)
    _interpret_timings(rng)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    run()
    print(f"# wrote {write_bench_json(tag='fused_epilogue')}")
