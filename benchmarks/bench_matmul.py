"""Table 2 / Fig. 7 reproduction: GEMM across backends x dtypes.

Measured on this container: XLA-CPU wall-clock (the 'sequential CPU'
stand-in) and interpret-mode Pallas (correctness twin of the TPU
kernel). Modeled: per-chip roofline times for the paper's accelerators
(C1060, C2050 naive/shared) and the v5e target, reported next to the
paper's own Table-2 seconds so the reproduction is checkable
column-by-column.

`run(autotune=True)` (the harness's --autotune flag) additionally
sweeps tile configs for the measured shapes via repro.tuning and
persists winners; every run reports whether the `tuned` backend is
being served from that cache.
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_matmul.py`
    import os
    import sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro import tuning
from repro.core import blocking, gemm, hw, precision
from repro.core.policy import Policy
from repro.configs.paper_gemm import CONFIG as PAPER

# Shapes the interpret-mode autotune sweep covers on this container.
# On a real TPU the same flag sweeps the compiled kernel instead
# (tuning.default_exec_backend picks the backend).
TUNE_SIZES = (256, 512)
TUNE_FLASH = (256, 512, 64)   # (tq, tk, head_dim)


def modeled_time(chip, n, itemsize, shared: bool) -> float:
    cfg = blocking.choose_block_config(n, n, n, itemsize, chip=chip) \
        if shared else None
    return blocking.gemm_time_model(n, n, n, itemsize, cfg, chip=chip)["t_total"]


def _autotune_sweep(policy: Policy) -> None:
    """Populate the tuning cache for the shapes this suite measures and
    report tuned-vs-default tile timings."""
    backend = policy.kernel_fingerprint           # emit-label component
    for n in TUNE_SIZES:
        res = tuning.tune_matmul(n, n, n, "float32", policy=policy,
                                 warmup=1, iters=2, max_candidates=6)
        b = res.best
        emit(f"autotune_matmul_{backend}_{n}", res.best_s,
             f"best=bm{b.bm}xbn{b.bn}xbk{b.bk};"
             f"default_us={res.baseline_s*1e6:.1f};"
             f"speedup_vs_default={res.speedup:.2f}x;"
             f"trials={len(res.trials)}")
    tq, tk, d = TUNE_FLASH
    res = tuning.tune_flash_attention(tq, tk, d, "float32", policy=policy,
                                      warmup=1, iters=2, max_candidates=4)
    emit(f"autotune_flash_{backend}_{tq}x{tk}", res.best_s,
         f"best=bq{res.best.bq}xbk{res.best.bk};"
         f"speedup_vs_default={res.speedup:.2f}x;trials={len(res.trials)}")
    cache = tuning.get_cache()
    print(f"# autotune: {len(cache)} entries cached at {cache.path} "
          f"(fingerprint {cache.fingerprint})")


def _tuned_serving_report(policy: Policy) -> None:
    """Measure the cached-autotune policy and say whether each shape's
    tiles came from the autotuner cache or fell back to the static
    chooser."""
    cache = tuning.get_cache(refresh=True)
    rng = np.random.default_rng(1)
    tuned_policy = policy.replace(autotune="cached")
    tuned_label = "tuned_interpret" if tuned_policy.resolved_interpret \
        else "tuned"
    for n in TUNE_SIZES:
        cfg = cache.get_matmul(n, n, n, "float32", policy)
        a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        f = lambda x, y: gemm.matmul(x, y, policy=tuned_policy)
        t = time_jax(f, a, a, warmup=1, iters=2)
        if cfg is not None:
            derived = (f"served_from_cache=True;"
                       f"config=bm{cfg.bm}xbn{cfg.bn}xbk{cfg.bk}")
        else:
            derived = "served_from_cache=False;fallback=static-chooser"
        emit(f"matmul_{tuned_label}_{n}", t, derived)


def run(autotune: bool = False) -> None:
    n = PAPER.n                                    # 4096, the paper's size
    rng = np.random.default_rng(0)

    # --- measured XLA-CPU wall-clock (this container's 'CPU column')
    for dtype, iters in (("float32", 3), ("complex64", 2)):
        a = jnp.asarray(rng.normal(size=(n, n)), dtype) \
            if dtype != "complex64" else jnp.asarray(
                rng.normal(size=(n, n)) + 1j * rng.normal(size=(n, n)),
                dtype)
        f = jax.jit(lambda x, y: gemm.matmul(x, y, policy=Policy()))
        t = time_jax(f, a, a, warmup=1, iters=iters)
        flops = precision.gemm_flops(n, n, n, dtype)
        emit(f"matmul_xla_cpu_{dtype}_{n}", t,
             f"gflops={flops/t/1e9:.1f}")

    # --- measured interpret-mode Pallas (kernel correctness twin)
    ni = 512
    a = jnp.asarray(rng.normal(size=(ni, ni)), jnp.float32)
    for backend in ("pallas_interpret", "naive_interpret"):
        pol = Policy.from_backend(backend)
        f = lambda x, y: gemm.matmul(x, y, policy=pol)
        t = time_jax(f, a, a, warmup=1, iters=2)
        emit(f"matmul_{backend}_{ni}", t,
             "interpreter-not-wallclock-meaningful")

    # --- tile autotuning (sweep + cache) and cached-policy serving
    exec_policy = tuning.default_exec_policy()
    if autotune:
        _autotune_sweep(exec_policy)
    _tuned_serving_report(exec_policy)

    # --- modeled Table 2 (per-chip roofline), float column
    paper = PAPER.reference_times
    rows = [
        ("tesla-c1060", hw.TESLA_C1060, False, paper[("tesla-c1060", "float32")]),
        ("tesla-c2050-naive", hw.TESLA_C2050, False, paper[("tesla-c2050", "float32")]),
        ("tesla-c2050-shared", hw.TESLA_C2050, True, paper[("tesla-c2050-shared", "float32")]),
    ]
    for name, chip, shared, t_paper in rows:
        t_model = modeled_time(chip, n, 4, shared)
        emit(f"matmul_model_{name}_f32_{n}", t_model,
             f"paper_measured_s={t_paper};model/paper={t_model/t_paper:.3f}")

    # --- modeled v5e target, the three paper dtypes
    for dtype, itemsize in (("bf16", 2), ("float32", 4), ("float64", 8)):
        t_model = modeled_time(hw.TPU_V5E, n, itemsize, True)
        flops = 2.0 * n ** 3
        emit(f"matmul_model_v5e_{dtype}_{n}", t_model,
             f"gflops={flops/t_model/1e9:.0f}")
    # complex64 via gauss3: 3 real f32 GEMMs (beyond-paper: 3 not 4)
    t3 = 3 * modeled_time(hw.TPU_V5E, n, 4, True)
    emit(f"matmul_model_v5e_complex64_gauss3_{n}", t3,
         f"vs_naive4={4*modeled_time(hw.TPU_V5E, n, 4, True)/t3:.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--autotune", action="store_true",
                    help="sweep tile configs and persist winners")
    run(autotune=ap.parse_args().autotune)
