"""Table 2 / Fig. 7 reproduction: GEMM across backends x dtypes.

Measured on this container: XLA-CPU wall-clock (the 'sequential CPU'
stand-in) and interpret-mode Pallas (correctness twin of the TPU
kernel). Modeled: per-chip roofline times for the paper's accelerators
(C1060, C2050 naive/shared) and the v5e target, reported next to the
paper's own Table-2 seconds so the reproduction is checkable
column-by-column.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import blocking, gemm, hw, precision
from repro.configs.paper_gemm import CONFIG as PAPER


def modeled_time(chip, n, itemsize, shared: bool) -> float:
    cfg = blocking.choose_block_config(n, n, n, itemsize, chip=chip) \
        if shared else None
    return blocking.gemm_time_model(n, n, n, itemsize, cfg, chip=chip)["t_total"]


def run() -> None:
    n = PAPER.n                                    # 4096, the paper's size
    rng = np.random.default_rng(0)

    # --- measured XLA-CPU wall-clock (this container's 'CPU column')
    for dtype, iters in (("float32", 3), ("complex64", 2)):
        a = jnp.asarray(rng.normal(size=(n, n)), dtype) \
            if dtype != "complex64" else jnp.asarray(
                rng.normal(size=(n, n)) + 1j * rng.normal(size=(n, n)),
                dtype)
        f = jax.jit(lambda x, y: gemm.matmul(x, y, backend="xla"))
        t = time_jax(f, a, a, warmup=1, iters=iters)
        flops = precision.gemm_flops(n, n, n, dtype)
        emit(f"matmul_xla_cpu_{dtype}_{n}", t,
             f"gflops={flops/t/1e9:.1f}")

    # --- measured interpret-mode Pallas (kernel correctness twin)
    ni = 512
    a = jnp.asarray(rng.normal(size=(ni, ni)), jnp.float32)
    for backend in ("pallas_interpret", "naive_interpret"):
        f = lambda x, y: gemm.matmul(x, y, backend=backend)
        t = time_jax(f, a, a, warmup=1, iters=2)
        emit(f"matmul_{backend}_{ni}", t,
             "interpreter-not-wallclock-meaningful")

    # --- modeled Table 2 (per-chip roofline), float column
    paper = PAPER.reference_times
    rows = [
        ("tesla-c1060", hw.TESLA_C1060, False, paper[("tesla-c1060", "float32")]),
        ("tesla-c2050-naive", hw.TESLA_C2050, False, paper[("tesla-c2050", "float32")]),
        ("tesla-c2050-shared", hw.TESLA_C2050, True, paper[("tesla-c2050-shared", "float32")]),
    ]
    for name, chip, shared, t_paper in rows:
        t_model = modeled_time(chip, n, 4, shared)
        emit(f"matmul_model_{name}_f32_{n}", t_model,
             f"paper_measured_s={t_paper};model/paper={t_model/t_paper:.3f}")

    # --- modeled v5e target, the three paper dtypes
    for dtype, itemsize in (("bf16", 2), ("float32", 4), ("float64", 8)):
        t_model = modeled_time(hw.TPU_V5E, n, itemsize, True)
        flops = 2.0 * n ** 3
        emit(f"matmul_model_v5e_{dtype}_{n}", t_model,
             f"gflops={flops/t_model/1e9:.0f}")
    # complex64 via gauss3: 3 real f32 GEMMs (beyond-paper: 3 not 4)
    t3 = 3 * modeled_time(hw.TPU_V5E, n, 4, True)
    emit(f"matmul_model_v5e_complex64_gauss3_{n}", t3,
         f"vs_naive4={4*modeled_time(hw.TPU_V5E, n, 4, True)/t3:.2f}x")


if __name__ == "__main__":
    run()
