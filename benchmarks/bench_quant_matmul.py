"""Int8-weight GEMM (matmul_q / dense_q) benchmark.

Three claims, each checkable on this CPU-only container:

  1. **Byte accounting (asserted).** Per-channel int8 weights cut the
     modeled HBM bytes of a decode-shaped dense GEMM by >= 45% (bf16
     activations) and a prefill-shaped one by >= 20%, from the same
     static traffic model as the Fig.-8 reproduction
     (roofline.analysis.quant_gemm_savings — modeled, so it holds in
     interpret mode and transfers to the TPU where it becomes
     wall-clock).
  2. **Token-exact dequant (asserted).** With matched tiles the fused
     flush-phase dequant is bit-identical in f32 to the unfused
     composition "widen Wq to f32, tiled GEMM, scale the output": both
     apply the per-channel scale to the same f32 accumulator values.
     The quantization error vs the UNQUANTIZED GEMM is also emitted and
     bounded (per-channel symmetric grid: |dY| <= sum_k |a| * scale/2).
  3. **VJP parity (asserted).** Gradients through the core.gemm.dense_q
     chokepoint match jax.grad of the dequantized jnp composition in x,
     scale and bias (the quantized path trains everything but the
     frozen int8 weight).

Interpreter wall-clock is also emitted for the mechanism record
(interpret timings are not TPU-meaningful — EXPERIMENTS §Autotune).
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_quant_matmul.py`
    import os
    import sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import blocking, gemm, precision
from repro.core.policy import Policy
from repro.kernels import ops
from repro.roofline import analysis

_PI = Policy(backend="pallas", interpret=True)

# Byte-accounting shapes: decode (one token per slot against a big
# weight — the weight stream IS the traffic) and prefill (activations
# amortise the weights).
DECODE_SHAPE = (8, 4096, 4096)              # (m, n, k)
PREFILL_SHAPE = (2048, 4096, 4096)
DECODE_FLOOR = 0.45                          # bf16 activations
PREFILL_FLOOR = 0.20
PREFILL_F32_FLOOR = 0.30                     # 4x weight shrink vs 2x

# Small shapes for the measured interpret-mode passes.
M, K, N = 128, 64, 256


def _byte_accounting() -> None:
    for tag, (m, n, k), floor in (("decode", DECODE_SHAPE, DECODE_FLOOR),
                                  ("prefill", PREFILL_SHAPE, PREFILL_FLOOR)):
        s = analysis.quant_gemm_savings(m, n, k, 2)   # bf16 activations
        emit(f"quant_gemm_hbm_bytes_{tag}_{m}x{n}x{k}", 0.0,
             f"quant_bytes={s['quant_bytes']};full_bytes={s['full_bytes']};"
             f"saved_frac={s['saved_frac']:.3f};floor={floor}")
        assert s["saved_frac"] >= floor, (
            f"int8 weights move only {s['saved_frac']:.1%} fewer HBM bytes "
            f"at {tag} shape {(m, n, k)} (floor {floor:.0%})")
    # f32 activations: the weight stream shrinks 4x instead of 2x
    s32 = analysis.quant_gemm_savings(*PREFILL_SHAPE, 4)
    emit("quant_gemm_hbm_bytes_prefill_f32", 0.0,
         f"saved_frac={s32['saved_frac']:.3f};floor={PREFILL_F32_FLOOR}")
    assert s32["saved_frac"] >= PREFILL_F32_FLOOR, (
        f"int8 weights move only {s32['saved_frac']:.1%} fewer HBM bytes "
        f"at the f32 prefill shape (floor {PREFILL_F32_FLOOR:.0%})")


def _token_exactness(rng) -> None:
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    wq, scale = precision.quantize_int8(w)
    cfg = blocking.choose_block_config(M, N, K, 4)
    fused = ops.matmul_q(a, wq, scale, policy=_PI, block=cfg)
    unfused = ops.matmul(a, wq.astype(jnp.float32), policy=_PI,
                         block=cfg) * scale
    exact = bool(jnp.all(fused == unfused))
    emit("quant_dequant_token_exact_f32", 0.0,
         f"bitwise_equal={exact};max_abs_err="
         f"{float(jnp.max(jnp.abs(fused - unfused))):.1e}")
    assert exact, "flush-phase dequant diverged from the unfused composition"

    # quantization error vs the unquantized GEMM, against the grid bound
    full = ops.matmul(a, w, policy=_PI, block=cfg)
    err = float(jnp.max(jnp.abs(fused - full)))
    bound = float(jnp.max(
        jnp.sum(jnp.abs(a), axis=1, keepdims=True)
        * precision.quant_error_bound(scale)))
    emit("quant_error_vs_f32", 0.0, f"max_abs_err={err:.2e};bound={bound:.2e}")
    assert err <= bound, (err, bound)


def _vjp_parity(rng) -> None:
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    wq, scale = precision.quantize_int8(w)

    def quant_loss(x_, s_, b_):
        return jnp.sum(gemm.dense_q(x_, wq, s_, b_, activation="silu",
                                    policy=_PI) ** 2)

    def ref_loss(x_, s_, b_):
        return jnp.sum(jax.nn.silu(
            x_ @ (wq.astype(jnp.float32) * s_) + b_) ** 2)

    grads = jax.grad(quant_loss, argnums=(0, 1, 2))(x, scale, b)
    refs = jax.grad(ref_loss, argnums=(0, 1, 2))(x, scale, b)
    err = max(float(jnp.max(jnp.abs(gi - ri)))
              for gi, ri in zip(grads, refs))
    ref_scale = max(float(jnp.max(jnp.abs(ri))) for ri in refs)
    emit("quant_dense_vjp_parity", 0.0,
         f"max_abs_err={err:.2e};ref_scale={ref_scale:.1e}")
    assert err <= 1e-3 * max(ref_scale, 1.0), \
        f"dense_q VJP diverged from the dequantized reference: {err}"


def _interpret_timings(rng) -> None:
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    wq, scale = precision.quantize_int8(w)

    t = time_jax(lambda x: ops.matmul_q(x, wq, scale, policy=_PI),
                 a, warmup=1, iters=2)
    emit("matmul_q_pallas_interpret", t, "int8-W-stream")
    t = time_jax(lambda x: ops.matmul(x, w, policy=_PI), a,
                 warmup=1, iters=2)
    emit("matmul_f32_pallas_interpret", t,
         "interpreter-not-wallclock-meaningful")


def run() -> None:
    rng = np.random.default_rng(11)
    _byte_accounting()
    _token_exactness(rng)
    _vjp_parity(rng)
    _interpret_timings(rng)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    run()
    print(f"# wrote {write_bench_json(tag='quant_matmul')}")
