"""Multi-accelerator GEMM (the paper's Tesla S2050 section).

Runs the three shard_map schedules on 8 forced-host devices in a
subprocess (the main process keeps the 1-device world), measures
wall-clock, and reports the ICI-byte model per schedule — the
quantified form of the paper's 'matrices must be very large to amortise
multi-GPU transfer' remark.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap

from benchmarks.common import emit
from repro.core.distributed import comm_model_bytes

_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import sharded_matmul
from repro.launch.mesh import axis_kw

mesh = jax.make_mesh((8,), ("model",), **axis_kw(1))
rng = np.random.default_rng(0)
m = k = n = 1024
a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
ref = a @ b
for sched in ("ring", "column", "row"):
    f = jax.jit(lambda x, y, s=sched: sharded_matmul(x, y, mesh, schedule=s))
    out = f(a, b); jax.block_until_ready(out)
    err = float(jnp.max(jnp.abs(out - ref)))
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); jax.block_until_ready(f(a, b))
        ts.append(time.perf_counter() - t0)
    print(f"RESULT {sched} {sorted(ts)[1]:.6f} {err:.2e}")
"""


def run() -> None:
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_SUB)],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    m = k = n = 1024
    for line in out.stdout.splitlines():
        if not line.startswith("RESULT"):
            continue
        _, sched, t, err = line.split()
        comm = comm_model_bytes(m, n, k, 8, 4, sched)
        emit(f"distributed_gemm_{sched}_8dev_{m}", float(t),
             f"maxerr={err};model_ici_bytes_per_dev={comm}")


if __name__ == "__main__":
    run()
