"""Framework-level step benchmark: reduced-config train and decode
steps per architecture family on this host (CPU). Wall-clock here is a
smoke-level throughput number; the TPU-target numbers live in the
roofline table (bench_roofline_table)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from benchmarks.common import emit, time_jax
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.training import train_loop as TL

ARCHS = ("qwen3-0.6b", "mixtral-8x22b", "mamba2-2.7b", "zamba2-1.2b",
         "whisper-tiny")


def run() -> None:
    rng = np.random.default_rng(0)
    for name in ARCHS:
        cfg = C.get_config(name, reduced=True)
        opt = AdamW(lr=1e-3)
        state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
        B, S = 4, 64
        data = SyntheticLM(vocab=cfg.vocab, seq_len=S, batch=B)
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model),
                                              jnp.dtype(cfg.dtype))
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
            batch["positions"] = jnp.asarray(pos, jnp.int32)
        if cfg.family == "encdec":
            batch["enc_frames"] = jnp.asarray(
                rng.normal(size=(B, cfg.enc_ctx, cfg.d_model)), jnp.float32)

        step = jax.jit(TL.make_train_step(cfg, opt))
        t = time_jax(step, state, batch, warmup=1, iters=3)
        emit(f"train_step_{name}_reduced_b{B}s{S}", t,
             f"tokens_per_s={B*S/t:.0f}")

        serve = jax.jit(TL.make_serve_step(cfg))
        cache = M.init_cache(cfg, B, 128)
        tok = jnp.zeros((B, 1), jnp.int32)
        td = time_jax(serve, state.params, tok, jnp.int32(64), cache,
                      warmup=1, iters=3)
        emit(f"decode_step_{name}_reduced_b{B}", td,
             f"tok_per_s={B/td:.0f}")


if __name__ == "__main__":
    run()
