"""SSD (Mamba-2) suite benchmark: fused intra-chunk kernel + routing.

Four claims, each checkable on this CPU-only container:

  1. **Byte accounting (asserted).** From the same static traffic
     models as the attention suite (core.blocking / roofline.analysis):
     at the mamba2-2.7b layer shape the fused intra-chunk kernel moves
     >= 40% fewer modeled HBM bytes than the XLA chunked lowering —
     the (Q, Q) decay mask and CB score block stay VMEM-resident
     instead of round-tripping in f32 (flash attention's argument with
     Q = chunk). Modeled, so it holds in interpret mode and transfers
     to the TPU where it becomes wall-clock.
  2. **Backend parity (asserted).** The pallas kernel matches the
     chunked oracle to f32 roundoff in f32 AND bf16, with and without
     a carried init_state (the contract bugs this PR fixed: unmasked
     decay exp, dropped init_state, x.dtype state seeding).
  3. **VJP parity (asserted).** Gradients through the core.ssd
     custom-VJP under a pallas policy match jax.grad through the
     unfused ssd_chunked composition — mamba2 trains under any policy.
  4. **Interpreter wall-clock (emitted).** Mechanism record only —
     interpret timings are not TPU-meaningful (EXPERIMENTS §Autotune).
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_ssd.py`
    import os
    import sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import ssd as core_ssd
from repro.core.policy import Policy
from repro.kernels import ops
from repro.kernels.ssd import ssd_chunked
from repro.roofline import analysis

_PI = Policy(backend="pallas", interpret=True)

# Byte-accounting shape: one mamba2-2.7b layer (H=40 heads of P=64,
# N=128 state, chunk=256) over a 4k prefill.
ACC_L, ACC_H, ACC_P, ACC_N, ACC_CHUNK = 4096, 40, 64, 128, 256
SSD_FLOOR = 0.40

# Small shapes for the measured interpret-mode passes.
B, L, CHUNK, H, G, P, N = 2, 64, 16, 4, 2, 16, 16


def _byte_accounting() -> None:
    s = analysis.ssd_savings(ACC_L, ACC_H, ACC_P, ACC_N, ACC_CHUNK, 4)
    cfg = s["cfg"]
    emit(f"ssd_hbm_bytes_l{ACC_L}_q{ACC_CHUNK}", 0.0,
         f"fused_bytes={s['fused_bytes']};unfused_bytes={s['unfused_bytes']};"
         f"saved_frac={s['saved_frac']:.3f};floor={SSD_FLOOR};"
         f"cfg=q{cfg.q}xbp{cfg.bp}")
    assert s["saved_frac"] >= SSD_FLOOR, (
        f"fused SSD moves only {s['saved_frac']:.1%} fewer HBM bytes "
        f"than the XLA lowering at the mamba2 shape (floor "
        f"{SSD_FLOOR:.0%})")


def _operands(rng, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), dtype)
    a = jnp.asarray(-np.abs(rng.normal(size=(B, L, H))) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, G, N)), dtype)
    c = jnp.asarray(rng.normal(size=(B, L, G, N)), dtype)
    return x, a, b, c


def _parity(rng) -> None:
    s0 = jnp.asarray(rng.normal(size=(B, H, P, N)), jnp.float32)
    for dtype, tol, tag in ((jnp.float32, 2e-5, "f32"),
                            (jnp.bfloat16, 6e-2, "bf16")):
        x, a, b, c = _operands(rng, dtype)
        for init in (None, s0):
            yk, sk = ops.ssd(x, a, b, c, CHUNK, init_state=init, policy=_PI)
            yr, sr = ssd_chunked(x, a, b, c, CHUNK, init_state=init)
            ey = float(jnp.max(jnp.abs(yk.astype(jnp.float32)
                                       - yr.astype(jnp.float32))))
            es = float(jnp.max(jnp.abs(sk - sr)))
            name = f"ssd_parity_{tag}" + ("_carried" if init is not None
                                          else "")
            emit(name, 0.0, f"max_abs_err_y={ey:.1e};max_abs_err_s={es:.1e}")
            assert ey <= tol and es <= max(tol, 1e-4), (
                f"ssd_pallas diverged from ssd_chunked ({tag}, "
                f"init={init is not None}): y={ey}, s={es}")


def _vjp_parity(rng) -> None:
    x, a, b, c = _operands(rng)

    def fused_loss(x_, a_, b_, c_):
        y, s = core_ssd.ssd(x_, a_, b_, c_, CHUNK, policy=_PI)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    def ref_loss(x_, a_, b_, c_):
        y, s = ssd_chunked(x_, a_, b_, c_, CHUNK)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    grads = jax.grad(fused_loss, argnums=(0, 1, 2, 3))(x, a, b, c)
    refs = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(x, a, b, c)
    err = max(float(jnp.max(jnp.abs(gi - ri)))
              for gi, ri in zip(grads, refs))
    ref_scale = max(float(jnp.max(jnp.abs(ri))) for ri in refs)
    emit("ssd_vjp_parity", 0.0,
         f"max_abs_err={err:.2e};ref_scale={ref_scale:.1e}")
    assert err <= 1e-3 * max(ref_scale, 1.0), \
        f"core.ssd VJP diverged from the unfused composition: {err}"


def _interpret_timings(rng) -> None:
    x, a, b, c = _operands(rng)
    t = time_jax(lambda *ops_: ops.ssd(*ops_, CHUNK, policy=_PI),
                 x, a, b, c, warmup=1, iters=2)
    emit("ssd_pallas_interpret", t, "interpreter-not-wallclock-meaningful")
    t = time_jax(lambda *ops_: ssd_chunked(*ops_, CHUNK),
                 x, a, b, c, warmup=1, iters=2)
    emit("ssd_chunked_xla", t, "unfused-baseline")


def run() -> None:
    rng = np.random.default_rng(29)
    _byte_accounting()
    _parity(rng)
    _vjp_parity(rng)
    _interpret_timings(rng)


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    run()
    print(f"# wrote {write_bench_json(tag='ssd')}")
