"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Output contract: ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (bench_add, bench_arch_step, bench_distributed_gemm,
                        bench_matmul, bench_roofline_table,
                        bench_shared_memory)

SUITES = {
    "matmul": bench_matmul.run,               # Table 2 / Fig 7
    "shared_memory": bench_shared_memory.run,  # Fig 8
    "add": bench_add.run,                      # Fig 9
    "distributed_gemm": bench_distributed_gemm.run,  # S2050 section
    "arch_step": bench_arch_step.run,          # framework-level
    "roofline_table": bench_roofline_table.run,  # deliverable (g)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("# FAILED suites:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
