"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--autotune]
    python benchmarks/run.py --autotune        # script form also works

Output contract: ``name,us_per_call,derived`` CSV lines, plus a
machine-readable ``BENCH_<git-rev>.json`` written at the end of every
run (benchmarks.common.write_bench_json) so the perf trajectory is
tracked across PRs — CI uploads it as an artifact.

--autotune runs the tile-autotuning sweep (repro.tuning) for the suites
that support it and persists winners to the tuning cache
($REPRO_TUNING_CACHE, default ~/.cache/repro/tuning.json); without
--only it restricts to those suites so cache population stays fast.
Subsequent runs report the `tuned` backend being served from the cache.
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/run.py`
    import os
    import sys as _sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in _sys.path:
            _sys.path.insert(0, _p)

import argparse
import sys
import traceback

from repro.core import policy as policy_mod
from repro.core.policy import LEGACY_BACKEND_NAMES, Policy

from benchmarks import (bench_add, bench_arch_step, bench_distributed_gemm,
                        bench_flash_attention, bench_fused_epilogue,
                        bench_matmul, bench_quant_matmul,
                        bench_roofline_table, bench_serving,
                        bench_shared_memory, bench_ssd, common)

SUITES = {
    "matmul": bench_matmul.run,               # Table 2 / Fig 7
    "shared_memory": bench_shared_memory.run,  # Fig 8
    "add": bench_add.run,                      # Fig 9
    "distributed_gemm": bench_distributed_gemm.run,  # S2050 section
    "arch_step": bench_arch_step.run,          # framework-level
    "roofline_table": bench_roofline_table.run,  # deliverable (g)
    "serving": bench_serving.run,              # continuous-batching engine
    "fused_epilogue": bench_fused_epilogue.run,  # fused-flush GEMM/SwiGLU
    "quant_matmul": bench_quant_matmul.run,    # int8-weight GEMM path
    "flash_attention": bench_flash_attention.run,  # fused fwd/bwd + decode
    "ssd": bench_ssd.run,                      # Mamba-2 SSD kernel suite
}

# Suites whose run() accepts autotune= and sweeps the tuner.
AUTOTUNABLE = frozenset({"matmul"})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--backend", choices=LEGACY_BACKEND_NAMES, default="xla",
                    help="ambient execution Policy for the run; suites "
                         "that sweep backends still pin their own")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep tile configs via repro.tuning and persist "
                         "winners to the tuning cache")
    args = ap.parse_args()

    # One typed Policy for the whole run: recorded in the BENCH json
    # (write_bench_json) so a result is reproducible from its file.
    policy_mod.set_default_policy(Policy.from_backend(args.backend))

    print("name,us_per_call,derived")
    failures = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        if args.autotune and not args.only and name not in AUTOTUNABLE:
            continue
        print(f"# --- {name} ---")
        try:
            if args.autotune and name in AUTOTUNABLE:
                fn(autotune=True)
            else:
                fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if common.bench_results():
        # machine-readable perf trajectory: the untagged BENCH_<rev>.json
        # is reserved for full runs; partial runs (--only / --autotune's
        # suite restriction) get a tag so they never clobber it.
        tag = args.only or ("autotune" if args.autotune else None)
        print(f"# wrote {common.write_bench_json(tag=tag)}")
    if failures:
        print("# FAILED suites:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
