"""Continuous-batching serving benchmark: prefill/decode throughput and
per-request latency percentiles across the workload scenario registry.

Every scenario resolves through ``serving.workload.TRACES`` (the same
registry ``serve.py --workload`` uses) and reports per-scenario
p50/p99 decode-step latency plus goodput in ONE table, so "where does
latency come from under THIS traffic shape" is a row lookup, not a
cross-file diff:

  * burst        — all requests at t=0, queueing on the slot pool;
  * poisson      — arrivals at a finite rate (the latency a request
    actually sees);
  * bursty_deadline — compound-Poisson groups + per-request deadlines
    (goodput / deadline-miss under the pool-exhaustion worst case);
  * prefix_heavy — shared system prompt (prefix sharing, and where
    speculation wins);
  * long_context — long prompts, short generations (prefill-bound).

A speculative-decoding section runs the draft/verify engine on the
prefix-heavy trace: a self-draft (draft params = target params, the
acceptance-rate ceiling) must push tokens-per-step past 1.5 (asserted
— this is the subsystem's reason to exist), while a mismatched random
draft and a temperature-sampling run show where speculation loses.
``spec_acceptance_rate`` / ``tokens_per_step`` ride the derived column
into the BENCH JSON via ``common.write_bench_json``.

A capacity section pits the paged KV cache against dense rows at EQUAL
KV byte budget on a prefix-heavy chat trace: the dense engine can only
afford a couple of max_len slots, while page granularity + shared
prefix pages + int8 pages buy strictly more concurrent occupancy from
the same bytes (asserted below, not just reported).

Output rows follow the harness contract `name,us_per_call,derived`
with us_per_call = mean per-request latency.
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_serving.py`
    import os
    import sys as _sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in _sys.path:
            _sys.path.insert(0, _p)

import jax
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.core.policy import Policy
from repro.models import model as M
from repro.serving import (ServingEngine, Sampler, make_sampler, make_trace,
                           prefix_heavy_trace)

ARCHS = ("qwen3-0.6b", "mamba2-2.7b", "zamba2-1.2b")
N_REQUESTS = 10
MAX_SLOTS = 4
GEN = 8
LEN_RANGE = (8, 48)           # inclusive, as in launch/serve.py

# bursty + deadline scenario (fault-tolerance accounting surface)
BURSTY_RATE = 16.0
BURSTY_MEAN = 4.0
BURSTY_DEADLINE = 30.0        # generous on CPU; misses only under chaos

#: (label, TRACES name, trace kwargs) — every scenario resolves through
#: the workload registry so the benchmark and serve.py --workload can
#: never drift apart on what a scenario means.
SCENARIOS = (
    ("burst", "mixed", dict(len_range=LEN_RANGE, gen=GEN,
                            arrival_rate=0.0)),
    ("poisson", "mixed", dict(len_range=LEN_RANGE, gen=GEN,
                              arrival_rate=8.0)),
    ("bursty_deadline", "bursty", dict(len_range=LEN_RANGE, gen=GEN,
                                       arrival_rate=BURSTY_RATE,
                                       burst_mean=BURSTY_MEAN,
                                       deadline=BURSTY_DEADLINE)),
    ("prefix_heavy", "prefix_heavy", dict(prefix_len=32,
                                          suffix_range=(2, 12), gen=GEN)),
    ("long_context", "long_context", dict(len_range=(96, 160), gen=4)),
)

# speculative decoding on the prefix-heavy trace (where drafts track)
SPEC_ARCH = "qwen3-0.6b"
SPEC_DRAFT = "granite-3-8b"   # mismatched-draft row (random params)
SPEC_K = 4
SPEC_REQUESTS = 8
SPEC_GEN = 8
SPEC_TPS_FLOOR = 1.5          # acceptance criterion: self-draft beats this

# prefix-heavy capacity shoot-out (equal KV bytes across layouts)
CAP_ARCH = "qwen3-0.6b"
CAP_REQUESTS = 8
CAP_PREFIX = 32
CAP_SUFFIX = (0, 6)
CAP_GEN = 6
CAP_PAGE = 16
CAP_DENSE_SLOTS = 2           # what the byte budget buys at max_len rows


def _submit_all(eng, trace):
    return [eng.submit(it.prompt, it.gen, arrival_time=it.arrival,
                       deadline=it.deadline, priority=it.priority,
                       enc_frames=it.enc_frames) for it in trace]


def _derived(rep, reqs) -> str:
    miss = rep["deadline_miss_rate"]
    return (f"prefill_tok_s={rep['prefill_tok_s']:.0f};"
            f"decode_tok_s={rep['decode_tok_s']:.0f};"
            f"occupancy={rep['mean_occupancy']:.2f};"
            f"lat_p50_ms={rep['latency_p50_s']*1e3:.0f};"
            f"lat_p95_ms={rep['latency_p95_s']*1e3:.0f};"
            f"ttft_p50_ms={rep['ttft_p50_s']*1e3:.0f};"
            f"decode_step_p50_ms={rep['decode_step_p50_s']*1e3:.2f};"
            f"decode_step_p99_ms={rep['decode_step_p99_s']*1e3:.2f};"
            f"adm_wait_p50_ms={rep['admission_wait_p50_s']*1e3:.0f};"
            f"adm_wait_p99_ms={rep['admission_wait_p99_s']*1e3:.0f};"
            f"goodput={rep['goodput']:.2f};"
            f"tokens_per_step={rep['tokens_per_step']:.2f};"
            f"expired={rep['expired']};cancelled={rep['cancelled']};"
            f"preempted={rep['preempted']};"
            f"quarantined={rep['quarantined']};"
            f"deadline_miss={'nan' if miss != miss else f'{miss:.2f}'}")


def _spec_derived(rep, reqs) -> str:
    return (_derived(rep, reqs)
            + f";spec_acceptance_rate={rep['spec_acceptance_rate']:.3f}"
            f";spec_rounds={rep['spec_rounds']}"
            f";spec_accepted={rep['spec_accepted']}"
            f";spec_proposed={rep['spec_proposed']}"
            f";draft_time_ms={rep['draft_time_s']*1e3:.0f}")


def _print_table(title: str, rows) -> None:
    """One aligned per-scenario table: decode-step p50/p99 + goodput —
    the cross-scenario comparison the per-row derived strings bury."""
    print(f"# {title}")
    hdr = f"# {'scenario':<18} {'p50_ms':>8} {'p99_ms':>8} " \
          f"{'goodput':>8} {'tok/step':>9}"
    print(hdr)
    for label, rep in rows:
        print(f"# {label:<18} {rep['decode_step_p50_s']*1e3:8.2f} "
              f"{rep['decode_step_p99_s']*1e3:8.2f} "
              f"{rep['goodput']:8.2f} {rep['tokens_per_step']:9.2f}")


def run() -> None:
    for name in ARCHS:
        cfg = C.get_config(name, reduced=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        table = []
        for label, trace_name, kw in SCENARIOS:
            rng = np.random.default_rng(0)
            trace = make_trace(trace_name, cfg, N_REQUESTS, rng=rng, **kw)
            max_len = max(len(it.prompt) + it.gen for it in trace)
            eng = ServingEngine(cfg, params, max_slots=MAX_SLOTS,
                                max_len=max_len)
            reqs = _submit_all(eng, trace)
            rep = eng.run()
            mean_lat = float(np.mean([r.latency for r in reqs
                                      if r.latency is not None]))
            table.append((label, rep))
            emit(f"serving_{name}_{label}_r{N_REQUESTS}s{MAX_SLOTS}",
                 mean_lat, _derived(rep, reqs))
        _print_table(f"scenario suite: {name}", table)
    run_speculative()
    run_paged_capacity()
    run_state_advantage()


def run_speculative() -> None:
    """Draft/verify engine on the prefix-heavy chat trace. Three rows:

    * self-draft, greedy — draft params = target params, the acceptance
      ceiling: every proposal the target would have emitted anyway is
      accepted, so tokens-per-step approaches spec_k + 1. Must clear
      SPEC_TPS_FLOOR (the subsystem's acceptance criterion).
    * mismatched draft, greedy — an unrelated random-weights draft:
      acceptance collapses to ~1/vocab and tokens-per-step to ~1. The
      "speculation loses" row; the stream is STILL token-exact (the
      rule guarantees it, tests/test_spec.py pins it).
    * self-draft, temperature — high-entropy sampling: even a perfect
      draft gets only p(x) acceptance per token, the distribution-
      identity tax. Shows why measured acceptance, not draft quality
      alone, must drive the spec_k choice (docs/EXPERIMENTS.md).
    """
    cfg = C.get_config(SPEC_ARCH, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dcfg = C.get_config(SPEC_DRAFT, reduced=True)
    dparams = M.init_params(dcfg, jax.random.PRNGKey(1))
    rows = []
    runs = (
        ("spec_self_greedy", (cfg, params), Sampler()),
        ("spec_mismatch_greedy", (dcfg, dparams), Sampler()),
        ("spec_self_temp", (cfg, params),
         make_sampler("temperature", temperature=1.0, seed=0)),
    )
    tps = {}
    for label, draft, sampler in runs:
        rng = np.random.default_rng(0)
        trace = prefix_heavy_trace(cfg, SPEC_REQUESTS, rng=rng,
                                   prefix_len=32, suffix_range=(2, 12),
                                   gen=SPEC_GEN)
        max_len = max(len(it.prompt) + it.gen for it in trace)
        eng = ServingEngine(cfg, params, max_slots=MAX_SLOTS,
                            max_len=max_len, sampler=sampler,
                            draft=draft, spec_k=SPEC_K)
        reqs = _submit_all(eng, trace)
        rep = eng.run()
        mean_lat = float(np.mean([r.latency for r in reqs
                                  if r.latency is not None]))
        tps[label] = rep["tokens_per_step"]
        rows.append((label, rep))
        emit(f"serving_{SPEC_ARCH}_{label}_k{SPEC_K}", mean_lat,
             _spec_derived(rep, reqs))
    _print_table(f"speculative decoding: {SPEC_ARCH} (k={SPEC_K})", rows)
    # the headline claim: batched verification + a draft that tracks the
    # target turns > 1.5 tokens per target step on prefix-heavy chat
    assert tps["spec_self_greedy"] > SPEC_TPS_FLOOR, tps
    print(f"# speculative tokens/step: {tps}")


def run_paged_capacity() -> None:
    """Dense vs paged vs paged+int8 on a prefix-heavy burst trace at
    EQUAL per-layer KV bytes. The byte budget is what CAP_DENSE_SLOTS
    dense slots cost; each paged layout converts the same bytes into as
    many pages as they buy. Asserts the paged+int8 engine reaches
    strictly higher peak concurrency than dense."""
    cfg = C.get_config(CAP_ARCH, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    itemsize = np.dtype(cfg.dtype).itemsize
    dh = cfg.resolved_head_dim
    max_len = CAP_PREFIX + CAP_SUFFIX[1] + CAP_GEN
    a = cfg.attn_chunk                       # engine rounds; mirror it
    if max_len > a and max_len % a:
        max_len += a - max_len % a
    row_full = 2 * cfg.n_kv_heads * dh * itemsize
    pool_bytes = CAP_DENSE_SLOTS * max_len * row_full

    peaks = {}
    for label, kv_layout, quant_kv in (("dense", "dense", "off"),
                                       ("paged", "paged", "off"),
                                       ("paged_int8", "paged", "int8")):
        pol = Policy(kv_layout=kv_layout, quant_kv=quant_kv)
        row = (2 * cfg.n_kv_heads * (dh + 4) if quant_kv == "int8"
               else row_full)
        kw = {}
        if kv_layout == "paged":
            kw = {"page_size": CAP_PAGE,
                  "kv_pool_pages": pool_bytes // (CAP_PAGE * row)}
        slots = CAP_DENSE_SLOTS if kv_layout == "dense" else CAP_REQUESTS
        eng = ServingEngine(cfg, params, max_slots=slots, max_len=max_len,
                            policy=pol, **kw)
        rng = np.random.default_rng(0)       # same trace for all three
        trace = prefix_heavy_trace(cfg, CAP_REQUESTS, rng=rng,
                                   prefix_len=CAP_PREFIX,
                                   suffix_range=CAP_SUFFIX, gen=CAP_GEN)
        reqs = _submit_all(eng, trace)
        rep = eng.run()
        mean_lat = float(np.mean([r.latency for r in reqs]))
        peaks[label] = rep["peak_occupancy"]
        derived = _derived(rep, reqs) + f";peak_occ={rep['peak_occupancy']}"
        if "kv_pool" in rep:
            kv = rep["kv_pool"]
            derived += (f";pool_pages={kv['n_pages']}"
                        f";peak_sharing={kv['peak_sharing_ratio']:.2f}"
                        f";cow={kv['cow_copies']}")
        emit(f"serving_capacity_{CAP_ARCH}_{label}", mean_lat, derived)

    # the headline claim: int8 pages + prefix sharing buy strictly more
    # concurrency than dense rows from the same bytes; f32 pages must at
    # least break even (sharing gains can be eaten by page rounding)
    assert peaks["paged_int8"] > peaks["dense"], peaks
    assert peaks["paged"] >= peaks["dense"], peaks
    print(f"# capacity peaks at equal KV bytes: {peaks}")


def run_state_advantage() -> None:
    """O(1)-state decode accounting: per-slot HBM bytes ONE decode step
    streams from recurrent/cache state at FULL model size, short vs
    long context (roofline.analysis — CPU-assertable like the capacity
    model). An attention layer re-reads its whole KV prefix every step
    (kv_decode_traffic_bytes grows with pos); a mamba layer re-reads
    one fixed (H, P, N) state. Asserts mamba2's bytes are position-
    independent and beat the transformer's at the long_context
    scenario's regime, and that hybrid zamba2 sits in between (only its
    shared attention block pays the O(pos) term)."""
    from repro.roofline import analysis as A

    pos_short, pos_long = 512, 32768
    bytes_at = {}
    for name in ARCHS:
        cfg = C.get_config(name)             # FULL size: real accounting
        sc = getattr(cfg, "ssm", None)
        n_attn = n_ssm = 0
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.attn_every
            n_ssm = cfg.n_layers
        elif cfg.family == "ssm":
            n_ssm = cfg.n_layers
        else:
            n_attn = cfg.n_layers
        itemsize = np.dtype(cfg.dtype).itemsize
        per_pos = {}
        for pos in (pos_short, pos_long):
            total = 0
            if n_attn:
                total += n_attn * A.kv_decode_traffic_bytes(
                    pos, cfg.n_kv_heads, cfg.resolved_head_dim, itemsize)
            if n_ssm:
                h = sc.expand * cfg.d_model // sc.head_dim
                total += n_ssm * A.ssm_decode_state_bytes(
                    h, sc.head_dim, sc.d_state)
            per_pos[pos] = total
        bytes_at[name] = per_pos
        growth = per_pos[pos_long] / per_pos[pos_short]
        emit(f"decode_state_bytes_{name}", 0.0,
             f"pos{pos_short}_bytes={per_pos[pos_short]};"
             f"pos{pos_long}_bytes={per_pos[pos_long]};"
             f"growth_x={growth:.2f}")

    mamba, qwen = bytes_at["mamba2-2.7b"], bytes_at["qwen3-0.6b"]
    zamba = bytes_at["zamba2-1.2b"]
    # O(1): the SSM bytes do not grow with position at all
    assert mamba[pos_long] == mamba[pos_short], mamba
    # and at long context they undercut the transformer's KV streaming
    assert mamba[pos_long] < qwen[pos_long], (mamba, qwen)
    # the hybrid pays the O(pos) term only on its shared attention block
    zgrow = zamba[pos_long] / zamba[pos_short]
    qgrow = qwen[pos_long] / qwen[pos_short]
    assert 1.0 < zgrow < qgrow, (zgrow, qgrow)
    print(f"# decode state bytes/slot at pos={pos_long}: "
          + ", ".join(f"{k}={v[pos_long]:,}" for k, v in bytes_at.items()))


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    run()
    print(f"# wrote {write_bench_json(tag='serving')}")
