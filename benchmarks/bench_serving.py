"""Continuous-batching serving benchmark: prefill/decode throughput and
per-request latency percentiles under a mixed-length arrival trace.

Two traces per arch on the reduced config (CPU smoke numbers; the
engine itself is what a TPU deployment would run):

  * burst  — all requests at t=0, queueing on the slot pool: measures
    steady-state decode tok/s and slot occupancy;
  * poisson — arrivals at a finite rate: measures the latency
    distribution (p50/p95) a request actually sees.

Output rows follow the harness contract `name,us_per_call,derived`
with us_per_call = mean per-request latency.
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_serving.py`
    import os
    import sys as _sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in _sys.path:
            _sys.path.insert(0, _p)

import jax
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.models import model as M
from repro.serving import ServingEngine, synthetic_trace

ARCHS = ("qwen3-0.6b", "mamba2-2.7b")
N_REQUESTS = 10
MAX_SLOTS = 4
GEN = 8
LEN_RANGE = (8, 48)           # inclusive, as in launch/serve.py


def run() -> None:
    for name in ARCHS:
        cfg = C.get_config(name, reduced=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        for label, rate in (("burst", 0.0), ("poisson", 8.0)):
            rng = np.random.default_rng(0)
            eng = ServingEngine(cfg, params, max_slots=MAX_SLOTS,
                                max_len=LEN_RANGE[1] + GEN)
            trace = synthetic_trace(cfg, N_REQUESTS, rng=rng,
                                    len_range=LEN_RANGE, gen=GEN,
                                    arrival_rate=rate)
            reqs = [eng.submit(p, g, arrival_time=t, enc_frames=e)
                    for p, g, t, e in trace]
            rep = eng.run()
            mean_lat = float(np.mean([r.latency for r in reqs]))
            emit(f"serving_{name}_{label}_r{N_REQUESTS}s{MAX_SLOTS}",
                 mean_lat,
                 f"prefill_tok_s={rep['prefill_tok_s']:.0f};"
                 f"decode_tok_s={rep['decode_tok_s']:.0f};"
                 f"occupancy={rep['mean_occupancy']:.2f};"
                 f"lat_p50_ms={rep['latency_p50_s']*1e3:.0f};"
                 f"lat_p95_ms={rep['latency_p95_s']*1e3:.0f};"
                 f"ttft_p50_ms={rep['ttft_p50_s']*1e3:.0f}")


if __name__ == "__main__":
    run()
