"""Continuous-batching serving benchmark: prefill/decode throughput and
per-request latency percentiles under a mixed-length arrival trace.

Three traces per arch on the reduced config (CPU smoke numbers; the
engine itself is what a TPU deployment would run):

  * burst  — all requests at t=0, queueing on the slot pool: measures
    steady-state decode tok/s and slot occupancy;
  * poisson — arrivals at a finite rate: measures the latency
    distribution (p50/p95) a request actually sees;
  * bursty — grouped arrivals (burst_size > 1) with per-request
    deadlines: measures goodput and the deadline-miss rate under the
    pool-exhaustion worst case a smooth trace never produces.

A fourth section pits the paged KV cache against dense rows at EQUAL
KV byte budget on a prefix-heavy chat trace: the dense engine can only
afford a couple of max_len slots, while page granularity + shared
prefix pages + int8 pages buy strictly more concurrent occupancy from
the same bytes (asserted below, not just reported).

Output rows follow the harness contract `name,us_per_call,derived`
with us_per_call = mean per-request latency.
"""

from __future__ import annotations

if __package__ in (None, ""):  # `python benchmarks/bench_serving.py`
    import os
    import sys as _sys
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in _sys.path:
            _sys.path.insert(0, _p)

import jax
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.core.policy import Policy
from repro.models import model as M
from repro.serving import ServingEngine, prefix_heavy_trace, synthetic_trace

ARCHS = ("qwen3-0.6b", "mamba2-2.7b")
N_REQUESTS = 10
MAX_SLOTS = 4
GEN = 8
LEN_RANGE = (8, 48)           # inclusive, as in launch/serve.py

# bursty + deadline scenario (fault-tolerance accounting surface)
BURSTY_RATE = 16.0
BURSTY_SIZE = 4
BURSTY_DEADLINE = 30.0        # generous on CPU; misses only under chaos

# prefix-heavy capacity shoot-out (equal KV bytes across layouts)
CAP_ARCH = "qwen3-0.6b"
CAP_REQUESTS = 8
CAP_PREFIX = 32
CAP_SUFFIX = (0, 6)
CAP_GEN = 6
CAP_PAGE = 16
CAP_DENSE_SLOTS = 2           # what the byte budget buys at max_len rows


def _submit_all(eng, trace):
    return [eng.submit(it.prompt, it.gen, arrival_time=it.arrival,
                       deadline=it.deadline, priority=it.priority,
                       enc_frames=it.enc_frames) for it in trace]


def _derived(rep, reqs) -> str:
    miss = rep["deadline_miss_rate"]
    return (f"prefill_tok_s={rep['prefill_tok_s']:.0f};"
            f"decode_tok_s={rep['decode_tok_s']:.0f};"
            f"occupancy={rep['mean_occupancy']:.2f};"
            f"lat_p50_ms={rep['latency_p50_s']*1e3:.0f};"
            f"lat_p95_ms={rep['latency_p95_s']*1e3:.0f};"
            f"ttft_p50_ms={rep['ttft_p50_s']*1e3:.0f};"
            f"decode_step_p50_ms={rep['decode_step_p50_s']*1e3:.2f};"
            f"decode_step_p99_ms={rep['decode_step_p99_s']*1e3:.2f};"
            f"adm_wait_p50_ms={rep['admission_wait_p50_s']*1e3:.0f};"
            f"adm_wait_p99_ms={rep['admission_wait_p99_s']*1e3:.0f};"
            f"goodput={rep['goodput']:.2f};"
            f"expired={rep['expired']};cancelled={rep['cancelled']};"
            f"preempted={rep['preempted']};"
            f"quarantined={rep['quarantined']};"
            f"deadline_miss={'nan' if miss != miss else f'{miss:.2f}'}")


def run() -> None:
    for name in ARCHS:
        cfg = C.get_config(name, reduced=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        scenarios = (
            ("burst", dict(arrival_rate=0.0)),
            ("poisson", dict(arrival_rate=8.0)),
            ("bursty_deadline", dict(arrival_rate=BURSTY_RATE,
                                     burst_size=BURSTY_SIZE,
                                     deadline=BURSTY_DEADLINE)),
        )
        for label, kw in scenarios:
            rng = np.random.default_rng(0)
            eng = ServingEngine(cfg, params, max_slots=MAX_SLOTS,
                                max_len=LEN_RANGE[1] + GEN)
            trace = synthetic_trace(cfg, N_REQUESTS, rng=rng,
                                    len_range=LEN_RANGE, gen=GEN, **kw)
            reqs = _submit_all(eng, trace)
            rep = eng.run()
            mean_lat = float(np.mean([r.latency for r in reqs
                                      if r.latency is not None]))
            emit(f"serving_{name}_{label}_r{N_REQUESTS}s{MAX_SLOTS}",
                 mean_lat, _derived(rep, reqs))
    run_paged_capacity()


def run_paged_capacity() -> None:
    """Dense vs paged vs paged+int8 on a prefix-heavy burst trace at
    EQUAL per-layer KV bytes. The byte budget is what CAP_DENSE_SLOTS
    dense slots cost; each paged layout converts the same bytes into as
    many pages as they buy. Asserts the paged+int8 engine reaches
    strictly higher peak concurrency than dense."""
    cfg = C.get_config(CAP_ARCH, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    itemsize = np.dtype(cfg.dtype).itemsize
    dh = cfg.resolved_head_dim
    max_len = CAP_PREFIX + CAP_SUFFIX[1] + CAP_GEN
    a = cfg.attn_chunk                       # engine rounds; mirror it
    if max_len > a and max_len % a:
        max_len += a - max_len % a
    row_full = 2 * cfg.n_kv_heads * dh * itemsize
    pool_bytes = CAP_DENSE_SLOTS * max_len * row_full

    peaks = {}
    for label, kv_layout, quant_kv in (("dense", "dense", "off"),
                                       ("paged", "paged", "off"),
                                       ("paged_int8", "paged", "int8")):
        pol = Policy(kv_layout=kv_layout, quant_kv=quant_kv)
        row = (2 * cfg.n_kv_heads * (dh + 4) if quant_kv == "int8"
               else row_full)
        kw = {}
        if kv_layout == "paged":
            kw = {"page_size": CAP_PAGE,
                  "kv_pool_pages": pool_bytes // (CAP_PAGE * row)}
        slots = CAP_DENSE_SLOTS if kv_layout == "dense" else CAP_REQUESTS
        eng = ServingEngine(cfg, params, max_slots=slots, max_len=max_len,
                            policy=pol, **kw)
        rng = np.random.default_rng(0)       # same trace for all three
        trace = prefix_heavy_trace(cfg, CAP_REQUESTS, rng=rng,
                                   prefix_len=CAP_PREFIX,
                                   suffix_range=CAP_SUFFIX, gen=CAP_GEN)
        reqs = _submit_all(eng, trace)
        rep = eng.run()
        mean_lat = float(np.mean([r.latency for r in reqs]))
        peaks[label] = rep["peak_occupancy"]
        derived = _derived(rep, reqs) + f";peak_occ={rep['peak_occupancy']}"
        if "kv_pool" in rep:
            kv = rep["kv_pool"]
            derived += (f";pool_pages={kv['n_pages']}"
                        f";peak_sharing={kv['peak_sharing_ratio']:.2f}"
                        f";cow={kv['cow_copies']}")
        emit(f"serving_capacity_{CAP_ARCH}_{label}", mean_lat, derived)

    # the headline claim: int8 pages + prefix sharing buy strictly more
    # concurrency than dense rows from the same bytes; f32 pages must at
    # least break even (sharing gains can be eaten by page rounding)
    assert peaks["paged_int8"] > peaks["dense"], peaks
    assert peaks["paged"] >= peaks["dense"], peaks
    print(f"# capacity peaks at equal KV bytes: {peaks}")


if __name__ == "__main__":
    run()
