"""Deliverable (g) surface: print the roofline table from the dry-run
artifacts (experiments/dryrun/*.json). The us_per_call column carries
the modeled dominant-term time per step on the target (v5e)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(pattern: str = "experiments/dryrun/*__singlepod.json") -> None:
    files = sorted(glob.glob(pattern))
    if not files:
        print("# no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    for path in files:
        with open(path) as f:
            r = json.load(f)
        name = os.path.basename(path).replace(".json", "")
        if r.get("skipped"):
            emit(f"roofline_{name}", 0.0, f"SKIPPED:{r['reason'][:60]}")
            continue
        dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        emit(f"roofline_{name}", dom,
             f"bound={r['bound']};tc={r['t_compute']:.4f}"
             f";tm={r['t_memory']:.4f};tcoll={r['t_collective']:.4f}"
             f";useful={r['useful_ratio']:.3f};mfu_roofline={r['mfu_roofline']:.4f}")


if __name__ == "__main__":
    run()
