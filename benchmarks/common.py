"""Shared benchmark utilities: timing, CSV emission, JSON persistence.

`time_jax` lives in repro.tuning.timing so the autotuner and the
benchmark tables score candidates with the same clock; this module
keeps the historical import site working.

Every `emit()` is recorded in a process-local registry;
`write_bench_json()` persists the registry as ``BENCH_<rev>.json``
(rev = short git hash of the working tree, "norev" outside a checkout)
so the perf trajectory is machine-tracked across PRs — CI uploads the
file as an artifact.
"""

from __future__ import annotations

import datetime
import json
import os
import subprocess

from repro.tuning.timing import time_jax  # noqa: F401  (re-export)

_RESULTS: list[dict] = []


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds*1e6:.1f},{derived}"
    _RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6, 3),
                     "derived": derived})
    print(line)
    return line


def bench_results() -> list[dict]:
    return list(_RESULTS)


def reset_results() -> None:
    _RESULTS.clear()


def _git_rev() -> str:
    """Short hash of HEAD, with a -dirty suffix when the working tree
    has uncommitted changes (so a pre-commit run can never overwrite
    the genuine record measured at that commit); "norev" outside a
    usable checkout."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=root)
        if out.returncode != 0:
            return "norev"
        rev = out.stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=root)
        if dirty.returncode == 0 and dirty.stdout.strip():
            rev += "-dirty"
        return rev
    except (OSError, subprocess.SubprocessError):
        return "norev"


def write_bench_json(directory: str | None = None,
                     tag: str | None = None) -> str:
    """Persist the emit() registry as BENCH_<rev>[_<tag>].json (repo
    root by default) and return the path. Re-running on the same rev
    overwrites — one file per (revision, tag) is the machine-readable
    contract; standalone suite __main__s pass their suite name as tag
    so they never clobber the harness's full-run file."""
    import jax

    from repro.core import policy as _pol

    rev = _git_rev()
    directory = directory or os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    name = f"BENCH_{rev}_{tag}.json" if tag else f"BENCH_{rev}.json"
    path = os.path.join(directory, name)
    doc = {
        "rev": rev,
        "generated_at": datetime.datetime.now().isoformat(
            timespec="seconds"),
        "jax": jax.__version__,
        "platform": jax.devices()[0].platform,
        # the ambient execution policy the run was driven under
        # (benchmarks/run.py --backend constructs it); individual
        # suites may still pin their own per-call policies.
        "policy": _pol.current_policy().fingerprint(),
        "results": bench_results(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path
