"""Shared benchmark utilities: timing, CSV emission."""

from __future__ import annotations

import time

import jax


def time_jax(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call of a jax function."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds*1e6:.1f},{derived}"
    print(line)
    return line
