"""Shared benchmark utilities: timing, CSV emission.

`time_jax` lives in repro.tuning.timing so the autotuner and the
benchmark tables score candidates with the same clock; this module
keeps the historical import site working.
"""

from __future__ import annotations

from repro.tuning.timing import time_jax  # noqa: F401  (re-export)


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds*1e6:.1f},{derived}"
    print(line)
    return line
