"""Fig. 9 reproduction: matrix add/sub gains nothing from acceleration.

The paper counts elementary CPU operations (hardware counter) and finds
add/sub transfer-bound. We reproduce the claim with the arithmetic-
intensity classifier plus measured wall-clock: GEMM vs add on the same
4096^2 operands — the add runs at memory bandwidth, the GEMM at
compute rate, on every chip model.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import hw, intensity
from repro.core.policy import Policy
from repro.kernels import ops

_PI = Policy.from_backend("pallas_interpret")


def run() -> None:
    n = 4096
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)

    t_add = time_jax(jax.jit(lambda x, y: ops.add(x, y)), a, b,
                     warmup=1, iters=5)
    emit(f"add_host_{n}", t_add,
         f"GBps={3*4*n*n/t_add/1e9:.1f}")

    # classifier: claim C3 on both chips
    for chip_name, chip in (("c2050", hw.TESLA_C2050), ("v5e", hw.TPU_V5E)):
        cl_add = intensity.classify(intensity.add_profile(n, n, 4),
                                    chip=chip, itemsize=4)
        cl_mm = intensity.classify(intensity.matmul_profile(n, n, n, 4),
                                   chip=chip, itemsize=4)
        emit(f"add_model_{chip_name}_{n}", cl_add["t_memory"],
             f"bound={cl_add['bound']};AI={cl_add['arithmetic_intensity']:.3f};"
             f"attainable_gflops={cl_add['attainable_flops']/1e9:.1f}")
        emit(f"matmul_model_{chip_name}_{n}_for_contrast",
             max(cl_mm["t_compute"], cl_mm["t_memory"]),
             f"bound={cl_mm['bound']};AI={cl_mm['arithmetic_intensity']:.0f}")

    # interpret-mode kernel twin (correctness; not wall-clock)
    s = 1024
    x = jnp.asarray(rng.normal(size=(s, s)), jnp.float32)
    t = time_jax(lambda p, q: ops.add(p, q, policy=_PI),
                 x, x, warmup=1, iters=2)
    emit(f"add_pallas_interpret_{s}", t, "interpreter")


if __name__ == "__main__":
    run()
