"""Fig. 8 reproduction: shared-memory (tiled) vs hierarchy-blind GEMM.

The paper measures 2.49s -> 0.83s (3.0x) on Fermi at 4096^2 float. We
report: (a) the HBM-traffic model for both kernels (the mechanism), (b)
modeled times on C2050 — checkable against the paper's 3.0x — and v5e,
(c) measured XLA-CPU wall-clock for a cache-blocked vs a forced-naive
(row-at-a-time dot) formulation, the same effect on this host's cache
hierarchy.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import blocking, hw


def run() -> None:
    n = 4096
    for chip_name, chip in (("c2050", hw.TESLA_C2050),
                            ("v5e", hw.TPU_V5E)):
        cfg = blocking.choose_block_config(n, n, n, 4, chip=chip)
        tiled = blocking.gemm_time_model(n, n, n, 4, cfg, chip=chip)
        naive = blocking.gemm_time_model(n, n, n, 4, None, chip=chip)
        emit(f"shared_memory_model_{chip_name}_tiled_{n}", tiled["t_total"],
             f"bound={tiled['bound']};traffic_GB={tiled['bytes']/1e9:.2f};"
             f"block={cfg.bm}x{cfg.bn}x{cfg.bk}")
        emit(f"shared_memory_model_{chip_name}_naive_{n}", naive["t_total"],
             f"bound={naive['bound']};traffic_GB={naive['bytes']/1e9:.2f};"
             f"speedup_tiled={naive['t_total']/tiled['t_total']:.1f}x"
             + (";paper_measured=3.0x" if chip_name == "c2050" else ""))

    # measured on this host: blocked (XLA dot) vs deliberately
    # hierarchy-blind (per-row dots; no k-blocking, no reuse)
    m = 1024
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)

    blocked = jax.jit(lambda x, y: x @ y)
    t_blocked = time_jax(blocked, a, b, warmup=1, iters=3)

    @jax.jit
    def rowwise(x, y):
        def body(_, row):
            return _, row @ y            # streams all of y per row
        _, out = jax.lax.scan(body, None, x)
        return out

    t_naive = time_jax(rowwise, a, b, warmup=1, iters=3)
    emit(f"shared_memory_host_blocked_{m}", t_blocked,
         f"gflops={2*m**3/t_blocked/1e9:.1f}")
    emit(f"shared_memory_host_rowwise_{m}", t_naive,
         f"speedup_blocked={t_naive/t_blocked:.2f}x")


if __name__ == "__main__":
    run()
