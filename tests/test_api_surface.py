"""Public-API covenant: `repro.__all__` must match the checked-in
snapshot (tests/api_surface.txt) and every name must resolve.

An intentional API change edits the snapshot file in the same PR — the
diff IS the review artifact. An accidental one fails here before it
ships."""

import os

import pytest

import repro

SNAPSHOT = os.path.join(os.path.dirname(__file__), "api_surface.txt")


def _snapshot_names():
    with open(SNAPSHOT) as f:
        return [ln.strip() for ln in f
                if ln.strip() and not ln.startswith("#")]


def test_all_matches_snapshot():
    expected = _snapshot_names()
    assert sorted(repro.__all__) == sorted(expected), (
        "repro.__all__ drifted from tests/api_surface.txt — if the "
        "change is intentional, update the snapshot in this PR")


def test_all_is_sorted_and_unique():
    assert list(repro.__all__) == sorted(set(repro.__all__))


@pytest.mark.parametrize("name", _snapshot_names())
def test_every_export_resolves(name):
    assert getattr(repro, name) is not None


def test_facade_is_lazy():
    """`import repro` must not drag jax in (fresh-interpreter check is
    CI's quickstart step; here we at least pin the lazy-export map)."""
    import repro as r
    assert set(r._EXPORTS) <= set(r.__all__)
