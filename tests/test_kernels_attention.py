"""Flash-attention kernel (interpret) + chunked XLA attention vs the
dense oracle, across GQA groupings, masks and chunk sizes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention
from repro.kernels.ref import attention_ref
from repro.models.attention import chunked_attention


def _qkv(rng, b, tq, tk, h, hkv, d, dtype="float32"):
    q = jnp.asarray(rng.normal(size=(b, tq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_kernel_vs_ref(rng, h, hkv, causal, window):
    q, k, v = _qkv(rng, 2, 128, 128, h, hkv, 32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          backend="pallas_interpret", bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [32, 64, 256])
@pytest.mark.parametrize("window", [None, 48])
def test_chunked_attention_vs_ref(rng, chunk, window):
    q, k, v = _qkv(rng, 2, 256, 256, 4, 2, 32)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_decode_offset(rng):
    """Decode semantics: 1 query at absolute position `pos` against a
    cache of kv_len valid entries."""
    tq, tk, pos = 1, 128, 57
    q, k, v = _qkv(rng, 2, tq, tk, 4, 4, 32)
    out = chunked_attention(q, k, v, causal=True, chunk=32,
                            q_offset=jnp.int32(pos), kv_len=jnp.int32(pos + 1))
    # oracle: dense attention over the first pos+1 keys only
    ref = attention_ref(q, k[:, :pos + 1], v[:, :pos + 1], causal=True,
                        q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 128, 128, 4, 2, 64, "bfloat16")
    out = flash_attention(q, k, v, causal=True, backend="pallas_interpret",
                          bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
