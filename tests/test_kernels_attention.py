"""Flash-attention suite: forward + lse residuals, the fused recompute
backward, the q_len=1 decode kernel, and the attention() router — every
Pallas path in interpret mode against the dense oracle and the chunked
XLA composition it replaced."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import Policy
from repro.kernels import ops
from repro.kernels.ops import flash_attention
from repro.kernels.ref import (attention_bwd_ref, attention_fwd_ref,
                               attention_ref, _LSE_EMPTY)
from repro.models.attention import attention, chunked_attention

_PI = Policy(backend="pallas", interpret=True)
_XLA = Policy(backend="xla")


def _qkv(rng, b, tq, tk, h, hkv, d, dtype="float32"):
    q = jnp.asarray(rng.normal(size=(b, tq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    return q, k, v


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_kernel_vs_ref(rng, h, hkv, causal, window):
    q, k, v = _qkv(rng, 2, 128, 128, h, hkv, 32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          backend="pallas_interpret", bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [32, 64, 256])
@pytest.mark.parametrize("window", [None, 48])
def test_chunked_attention_vs_ref(rng, chunk, window):
    q, k, v = _qkv(rng, 2, 256, 256, 4, 2, 32)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_decode_offset(rng):
    """Decode semantics: 1 query at absolute position `pos` against a
    cache of kv_len valid entries."""
    tq, tk, pos = 1, 128, 57
    q, k, v = _qkv(rng, 2, tq, tk, 4, 4, 32)
    out = chunked_attention(q, k, v, causal=True, chunk=32,
                            q_offset=jnp.int32(pos), kv_len=jnp.int32(pos + 1))
    # oracle: dense attention over the first pos+1 keys only
    ref = attention_ref(q, k[:, :pos + 1], v[:, :pos + 1], causal=True,
                        q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 128, 128, 4, 2, 64, "bfloat16")
    out = flash_attention(q, k, v, causal=True, backend="pallas_interpret",
                          bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_per_row_q_offset(rng):
    """Decode-style per-row offset vector: each batch row attends its
    own prefix depth through the SMEM operand, matching per-row dense."""
    b, tq, tk = 3, 8, 64
    q, k, v = _qkv(rng, b, tq, tk, 4, 2, 32)
    offs = jnp.asarray([0, 13, 56 - tq], jnp.int32)
    out = flash_attention(q, k, v, causal=True, q_offset=offs,
                          backend="pallas_interpret", bq=8, bk=32)
    ref = attention_ref(q, k, v, causal=True, q_offset=offs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_fwd_lse_matches_ref(rng):
    """The saved logsumexp residual (scaled-logit units) matches the
    dense oracle's — including the +1e30 sentinel on rows the causal
    mask empties (q_offset < 0 rows see no valid keys)."""
    q, k, v = _qkv(rng, 2, 64, 64, 4, 2, 32)
    o, lse = ops.flash_attention_fwd(q, k, v, causal=True, policy=_PI)
    o_ref, lse_ref = attention_fwd_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               rtol=1e-5, atol=1e-5)
    # fully-masked rows: q_offset = -tq puts every query before key 0
    offs = jnp.asarray([-64, 0], jnp.int32)
    _, lse2 = ops.flash_attention_fwd(q, k, v, causal=True, q_offset=offs,
                                      policy=_PI)
    assert bool(jnp.all(lse2[0] == _LSE_EMPTY))
    assert bool(jnp.all(jnp.isfinite(lse2[1])))


# ----------------------------------------------------------------------
# fused backward
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [("float32", 2e-4), ("bfloat16", 4e-2)])
@pytest.mark.parametrize("h,hkv,causal,window",
                         [(4, 4, True, None), (4, 2, True, None),
                          (8, 1, False, None), (4, 2, True, 48)])
def test_fused_vjp_matches_chunked_grads(rng, dtype, tol, h, hkv, causal,
                                         window):
    """The tentpole contract: gradients through attention()'s fused
    custom-VJP (flash fwd saving lse + the two-sweep recompute bwd)
    match differentiating through the chunked composition it replaced —
    across dtype, GQA grouping, and masks."""
    q, k, v = _qkv(rng, 2, 128, 128, h, hkv, 32, dtype)

    def fused_loss(q_, k_, v_):
        out = attention(q_, k_, v_, causal=causal, window=window,
                        chunk=64, policy=_PI)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def chunked_loss(q_, k_, v_):
        out = chunked_attention(q_, k_, v_, causal=causal, window=window,
                                chunk=64)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    grads = jax.grad(fused_loss, argnums=(0, 1, 2))(q, k, v)
    refs = jax.grad(chunked_loss, argnums=(0, 1, 2))(q, k, v)
    for name, g, r in zip(("dq", "dk", "dv"), grads, refs):
        assert g.dtype == r.dtype, name
        gf, rf = g.astype(jnp.float32), r.astype(jnp.float32)
        bound = tol * max(float(jnp.max(jnp.abs(rf))), 1.0)
        err = float(jnp.max(jnp.abs(gf - rf)))
        assert err <= bound, (name, err, bound)


def test_fused_vjp_check_grads(rng):
    """Numerical-derivative check on the custom VJP itself (small shape:
    check_grads runs O(inputs) forward evaluations)."""
    from jax.test_util import check_grads
    q, k, v = _qkv(rng, 1, 16, 16, 2, 1, 8)
    check_grads(
        lambda q_, k_, v_: attention(q_, k_, v_, causal=True, window=None,
                                     chunk=16, policy=_PI),
        (q, k, v), order=1, modes=["rev"], rtol=2e-3, atol=2e-3)


def test_flash_bwd_op_matches_closed_form(rng):
    """Registry-level parity: both flash_attention_bwd backends agree
    with the closed-form dense backward from the same residuals."""
    q, k, v = _qkv(rng, 2, 64, 64, 4, 2, 32)
    do = jnp.asarray(np.random.default_rng(7).normal(size=q.shape),
                     jnp.float32)
    o, lse = attention_fwd_ref(q, k, v, causal=True)
    refs = attention_bwd_ref(q, k, v, o, do, lse, causal=True)
    for pol in (_PI, _XLA):
        grads = ops.flash_attention_bwd(q, k, v, o, do, lse, causal=True,
                                        policy=pol)
        for name, g, r in zip(("dq", "dk", "dv"), grads, refs):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), rtol=2e-4, atol=2e-4,
                err_msg=f"{pol.backend}:{name}")


def test_ragged_shapes_fall_back_chunked_and_differentiate(rng):
    """tq=300 is not block-divisible: the pallas policy must route the
    chunked path (same values as xla) and stay differentiable."""
    q, k, v = _qkv(rng, 1, 300, 300, 4, 2, 32)

    def loss(pol):
        return lambda q_: jnp.sum(attention(
            q_, k, v, causal=True, window=None, chunk=60, policy=pol) ** 2)

    np.testing.assert_allclose(
        np.asarray(jax.grad(loss(_PI))(q)),
        np.asarray(jax.grad(loss(_XLA))(q)), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# decode kernel
# ----------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 24])
def test_flash_decode_vs_ref(rng, window):
    """Ragged per-slot depths against the dense oracle, window incl."""
    b, tk = 3, 128
    q, k, v = _qkv(rng, b, 1, tk, 4, 2, 32)
    pos = jnp.asarray([tk - 1, 37, 0], jnp.int32)
    out = ops.flash_decode(q, k, v, pos=pos, window=window, policy=_PI)
    ref, _ = attention_fwd_ref(q, k, v, causal=True, window=window,
                               q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_inactive_slot_is_finite_zero(rng):
    """pos < 0 marks an inactive slot: every K/V block is skipped, the
    flush's l==0 guard yields zeros (finite — NaNs would poison the
    batched engine step), and both backends agree on it."""
    q, k, v = _qkv(rng, 2, 1, 64, 4, 2, 32)
    pos = jnp.asarray([-1, 63], jnp.int32)
    out_p = ops.flash_decode(q, k, v, pos=pos, policy=_PI)
    out_x = ops.flash_decode(q, k, v, pos=pos, policy=_XLA)
    assert bool(jnp.all(jnp.isfinite(out_p)))
    assert bool(jnp.all(out_p[0] == 0.0))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16(rng):
    q, k, v = _qkv(rng, 2, 1, 128, 4, 2, 64, "bfloat16")
    pos = jnp.asarray([127, 40], jnp.int32)
    out = ops.flash_decode(q, k, v, pos=pos, policy=_PI)
    ref, _ = attention_fwd_ref(q, k, v, causal=True, q_offset=pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_attention_router_decode_matches_chunked(rng):
    """attention(decode=True) under a pallas policy takes the decode
    kernel and agrees with the chunked masked path on active slots."""
    b, tk = 2, 128
    q, k, v = _qkv(rng, b, 1, tk, 4, 2, 32)
    pos = jnp.asarray([100, 17], jnp.int32)
    out = attention(q, k, v, causal=True, window=None, chunk=64,
                    q_offset=pos, kv_len=pos + 1, policy=_PI, decode=True)
    ref = chunked_attention(q, k, v, causal=True, window=None, chunk=64,
                            q_offset=pos, kv_len=pos + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# f64 reroute
# ----------------------------------------------------------------------

def test_float64_reroutes_to_xla():
    """f64 attention under a pallas policy must land on the XLA path
    (the kernel accumulates f32 by construction): output stays f64 and
    is BITWISE identical to the explicit xla-policy result — same code
    path, not a lookalike — and gradients flow. Subprocess — x64 is a
    process-global switch."""
    code = textwrap.dedent("""
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.core.policy import Policy
        from repro.models.attention import attention
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float64)
        kv = jnp.asarray(rng.normal(size=(1, 64, 1, 16)), jnp.float64)
        pol = Policy(backend="pallas", interpret=True)
        out = attention(q, kv, kv, causal=True, window=None, chunk=32,
                        policy=pol)
        ref = attention(q, kv, kv, causal=True, window=None, chunk=32,
                        policy=Policy(backend="xla"))
        assert out.dtype == jnp.float64, out.dtype
        assert bool(jnp.all(out == ref)), "pallas policy did not reroute"
        g = jax.grad(lambda x: jnp.sum(attention(
            x, kv, kv, causal=True, window=None, chunk=32,
            policy=pol) ** 2))(q)
        assert g.dtype == jnp.float64 and bool(jnp.all(jnp.isfinite(g)))
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]
