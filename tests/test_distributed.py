"""Distributed tests. Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process keeps the real 1-device world, per the spec)."""

import json
import subprocess
import sys
import textwrap

import pytest

from repro.distributed import sharding as SH
from jax.sharding import PartitionSpec as P


def run_sub(code: str) -> str:
    env_code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
    """) + textwrap.dedent(code)
    out = subprocess.run([sys.executable, "-c", env_code],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_matmul_schedules():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import sharded_matmul
        from repro.launch.mesh import axis_kw
        mesh = jax.make_mesh((8,), ("model",), **axis_kw(1))
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
        ref = a @ b
        for sched in ("ring", "column", "row"):
            out = sharded_matmul(a, b, mesh, schedule=sched)
            err = float(jnp.max(jnp.abs(out - ref)))
            assert err < 1e-3, (sched, err)
        print("OK")
    """)
    assert "OK" in out


def test_train_step_pjit_multidevice_matches_single():
    """The sharded train step must be numerically equivalent to the
    single-device step (same seed, same batch)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        import repro.configs as C
        from repro.distributed import sharding as SH
        from repro.distributed.context import mesh_context
        from repro.launch.mesh import make_host_mesh
        from repro.optim.adamw import AdamW
        from repro.training import train_loop as TL
        from repro.data.pipeline import SyntheticLM

        cfg = C.get_config("qwen3-0.6b", reduced=True)
        opt = AdamW(lr=1e-3)
        data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))

        state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
        s_single, m_single = TL.make_train_step(cfg, opt)(state, batch)

        mesh = make_host_mesh(model_parallel=2)   # 4 data x 2 model
        pspecs = SH.param_specs(state.params, mesh)
        psh = SH.shardings_for(mesh, pspecs)
        state2 = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
        state2 = state2._replace(
            params=jax.device_put(state2.params, psh),
            opt=state2.opt._replace(m=jax.device_put(state2.opt.m, psh),
                                    v=jax.device_put(state2.opt.v, psh)))
        with mesh, mesh_context(mesh):
            step = jax.jit(TL.make_train_step(cfg, opt))
            s_multi, m_multi = step(state2, batch)
        dl = abs(float(m_single["loss"]) - float(m_multi["loss"]))
        assert dl < 1e-3, dl
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            s_single.params, jax.device_get(s_multi.params))
        worst = max(jax.tree.leaves(diffs))
        assert worst < 5e-3, worst
        print("OK", dl, worst)
    """)
    assert "OK" in out


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Checkpoint written on an 8-device mesh must restore onto a
    4-device mesh (elastic re-mesh after losing half the fleet)."""
    out = run_sub(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer({str(tmp_path)!r})
        from repro.launch.mesh import axis_kw
        mesh8 = jax.make_mesh((4, 2), ("data", "model"), **axis_kw(2))
        w = jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)
        w8 = jax.device_put(w, NamedSharding(mesh8, P("data", "model")))
        ck.save(1, {{"w": w8}})

        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        from jax.sharding import Mesh
        mesh4 = Mesh(devs, ("data", "model"))
        sh4 = {{"w": NamedSharding(mesh4, P("data", "model"))}}
        out = ck.restore(1, {{"w": w}}, shardings=sh4)
        assert out["w"].sharding.mesh.devices.size == 4
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        print("OK")
    """)
    assert "OK" in out


def test_param_spec_rules():
    """Sharding rules: spot-check the path->spec table (no mesh)."""
    assert SH.spec_for("layers/attn/wq/w", (28, 1024, 2048)) == \
        P(None, "data", "model")
    assert SH.spec_for("layers/moe/w_gate", (56, 8, 6144, 16384)) == \
        P(None, "model", "data", None)
    assert SH.spec_for("embed/w", (151936, 1024)) == P("model", "data")
    assert SH.spec_for("final_norm/scale", (1024,)) == P(None)
    assert SH.spec_for("hybrid/mamba/mamba/in_proj/w",
                       (6, 6, 2048, 8448)) == \
        P(None, None, "data", "model")


def test_param_spec_divisibility_fallback():
    """Mixtral's 8 experts on a 16-wide model axis must fall back to
    the TP-inside-expert candidate."""
    import jax
    from repro.launch.mesh import axis_kw
    mesh = jax.make_mesh((1, 1), ("data", "model"), **axis_kw(2))
    # fake a 16-wide model axis via divisibility check paths:
    spec = SH.spec_for("layers/moe/w_gate", (56, 8, 6144, 16384), None)
    assert spec == P(None, "model", "data", None)   # no mesh: first rule


def test_batch1_cache_replicates():
    """long_500k (batch=1) cache leaves must not claim the data axis."""
    import jax
    import repro.configs as C
    from repro.launch import specs as S
    from repro.configs.base import get_shape
    cfg = C.get_config("mamba2-2.7b")
    cell = get_shape("long_500k")
    cache = S.cache_specs_struct(cfg, cell)
    from repro.launch.mesh import axis_kw
    mesh = jax.make_mesh((1, 1), ("data", "model"), **axis_kw(2))
    specs = SH.cache_specs(cache, mesh, multi_pod=False)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        pass  # structure validated by construction
