"""Roofline/HLO-analyzer tests: trip-count awareness, remat detection,
collective parsing, report construction."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hlo as H
from repro.roofline.analysis import build_report, count_params, model_flops
import repro.configs as C
from repro.configs.base import get_shape


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def layer(x, w):
        return jnp.tanh(x @ w)

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)[0]

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    c = _compile(f, xs, ws)
    costs = H.analyze(c.as_text(), 1)
    expect = 2 * 64 * 128 * 128 * 12
    assert abs(costs.flops - expect) / expect < 0.02
    # XLA's own number undercounts by the trip count (the known gap)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # jax <= 0.4.x wraps it per-device
        ca = ca[0]
    assert ca["flops"] * 6 < costs.flops


def test_remat_recompute_visible():
    def layer(x, w):
        return jnp.tanh(x @ w)

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)[0]

    def f_remat(x, ws):
        body = jax.checkpoint(lambda c, w: (layer(c, w), None))
        return jax.lax.scan(body, x, ws)[0]

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    g = lambda fn: (lambda x, w: jnp.sum(fn(x, w) ** 2))
    plain = H.analyze(_compile(jax.grad(g(f), argnums=1), xs, ws).as_text(), 1)
    remat = H.analyze(_compile(jax.grad(g(f_remat), argnums=1), xs, ws)
                      .as_text(), 1)
    # remat adds ~1 extra forward: 4/3 of the plain grad flops
    ratio = remat.flops / plain.flops
    assert 1.25 < ratio < 1.45, ratio


def test_collective_parse_and_ici_model():
    hlo_text = """
HloModule test

ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %a = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%a), replica_groups=[4,2]<=[8], to_apply=%x
  ROOT %ag = f32[16,128]{1,0} all-gather(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
}
"""
    costs = H.analyze(hlo_text, 8)
    summ = costs.collective_summary()
    assert summ["all-reduce"]["count"] == 1
    ar = [c for c in costs.collectives if c.op.startswith("all-reduce")][0]
    ag = [c for c in costs.collectives if c.op.startswith("all-gather")][0]
    assert ar.group_size == 2
    assert ag.group_size == 4
    n = 16 * 128 * 4
    assert abs(ar.ici_bytes - 2 * n * 1 / 2) < 1
    assert abs(ag.ici_bytes - n * 3 / 4) < 1


def test_model_flops_conventions():
    cfg = C.get_config("qwen3-0.6b")
    cell = get_shape("train_4k")
    total, active = count_params(cfg)
    assert active == total                      # dense
    mf = model_flops(cfg, cell, kind="train")
    assert mf == 6.0 * total * cell.global_batch * cell.seq_len

    moe_cfg = C.get_config("mixtral-8x22b")
    t2, a2 = count_params(moe_cfg)
    assert a2 < t2 / 2                          # top-2 of 8 experts


def test_report_bounds_and_terms():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    xs = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    c = _compile(f, xs, ws)
    cfg = C.get_config("qwen3-0.6b")
    rep = build_report(cfg, get_shape("train_4k"), kind="train",
                       mesh_name="1", n_devices=1, hlo_text=c.as_text())
    assert rep.bound in ("compute", "memory", "collective")
    assert rep.t_compute > 0 and rep.t_memory > 0
    assert rep.t_collective == 0.0              # no collectives on 1 dev


def test_kv_traffic_and_quant_savings_thresholds():
    from repro.roofline.analysis import kv_decode_traffic_bytes, \
        kv_quant_savings
    # exact bookkeeping: (pos + 1) rows per side, heads * d elements
    assert kv_decode_traffic_bytes(15, 4, 64, 2) == 2 * 16 * 4 * 64 * 2
    assert kv_decode_traffic_bytes(15, 4, 64, 2, quant_kv="int8") == \
        2 * 16 * 4 * (64 + 4)
    # acceptance bar: int8 KV pages cut decode KV traffic by >= 40%
    for d in (64, 128):
        for itemsize in (2, 4):
            s = kv_quant_savings(255, 8, d, itemsize)
            assert s["saved_frac"] >= 0.40, (d, itemsize, s)
    # wider rows amortize the per-row scale better
    assert kv_quant_savings(255, 8, 128, 2)["saved_frac"] > \
        kv_quant_savings(255, 8, 64, 2)["saved_frac"]


def test_ssd_traffic_model_thresholds():
    from repro.core.blocking import SSDBlockConfig, choose_ssd_config
    from repro.roofline.analysis import ssd_savings, ssm_decode_state_bytes
    # exact bookkeeping: one (H, P, N) f32 state, read + write, per step
    assert ssm_decode_state_bytes(4, 8, 16) == 2 * 4 * 8 * 16 * 4
    # acceptance bar: the fused intra-chunk kernel cuts modeled HBM
    # bytes >= 40% at the mamba2-2.7b layer shape (the quadratic decay
    # mask + CB score round trips stay VMEM-resident)
    s = ssd_savings(4096, 40, 64, 128, 256, 4)
    assert s["saved_frac"] >= 0.40, s
    assert s["fused_bytes"] < s["unfused_bytes"]
    # the static chooser's pick must fit the double-buffered VMEM budget
    cfg = choose_ssd_config(256, 64, 128, 4)
    from repro.core.hw import TPU_V5E
    assert cfg.vmem_bytes(128, 4) <= TPU_V5E.vmem_bytes * 0.5 + 1
    assert 256 % cfg.q == 0 and 64 % cfg.bp == 0
    # longer chunks round-trip quadratically more unfused bytes; the
    # fused side only grows linearly in the extra scan traffic
    s_long = ssd_savings(4096, 40, 64, 128, 512, 4,
                         cfg=SSDBlockConfig(q=256, bp=64))
    assert s_long["unfused_bytes"] > s["unfused_bytes"]


def test_kv_capacity_model_prefix_heavy_2x():
    from repro.roofline.analysis import kv_capacity_model
    kw = dict(max_len=64, page_size=16, heads=4, d=64, itemsize=4,
              prompt_len=40, shared_prefix_len=32, gen=8)
    pool = 2 * 64 * (2 * 4 * 64 * 4)        # exactly 2 dense slots' bytes
    f32 = kv_capacity_model(pool, **kw)
    q8 = kv_capacity_model(pool, quant_kv="int8", **kw)
    assert f32["dense_slots"] == 2
    # acceptance bar: >= 2x concurrent slots on the prefix-heavy trace
    assert f32["capacity_ratio"] >= 2.0
    assert q8["capacity_ratio"] >= 2.0
    assert q8["paged_slots"] > f32["paged_slots"]   # int8 pages stack up
    assert q8["n_pages"] > f32["n_pages"]
