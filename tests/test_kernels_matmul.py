"""Per-kernel allclose sweeps: Pallas (interpret mode) vs ref.py oracle,
over shapes x dtypes — including the paper's float / double / complex
matrix (Table 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm, precision
from repro.kernels import ops
from repro.kernels.matmul import matmul_tiled
from repro.kernels.matmul_naive import matmul_naive
from repro.kernels.ref import matmul_ref

SHAPES = [
    (8, 8, 8),
    (128, 128, 128),
    (256, 384, 512),
    (100, 130, 50),      # ragged: exercises the padding path via ops
    (512, 256, 1024),
]


def _mats(rng, m, n, k, dtype):
    if np.dtype(dtype).kind == "c":
        a = rng.normal(size=(m, k)) + 1j * rng.normal(size=(m, k))
        b = rng.normal(size=(k, n)) + 1j * rng.normal(size=(k, n))
    else:
        a = rng.normal(size=(m, k))
        b = rng.normal(size=(k, n))
    return jnp.asarray(a, dtype), jnp.asarray(b, dtype)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_tiled_matches_ref(rng, m, n, k, dtype):
    a, b = _mats(rng, m, n, k, dtype)
    out = ops.matmul(a, b, backend="pallas_interpret")
    ref = matmul_ref(a, b)
    tol = 1e-5 if dtype == "float32" else 1e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("m,n,k", SHAPES[:4])
def test_naive_matches_ref(rng, m, n, k):
    a, b = _mats(rng, m, n, k, "float32")
    out = ops.matmul(a, b, backend="naive_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-4)


def test_float64_interpret():
    """The paper's double column: validated in interpret mode w/ x64.
    Runs in a subprocess — x64 is a process-global switch."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.kernels.matmul import matmul_tiled
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(128, 96)), jnp.float64)
        b = jnp.asarray(rng.normal(size=(96, 64)), jnp.float64)
        out = matmul_tiled(a, b, bm=64, bn=64, bk=32, interpret=True)
        err = float(jnp.max(jnp.abs(out - np.asarray(a) @ np.asarray(b))))
        assert out.dtype == jnp.float64 and err < 1e-12, (out.dtype, err)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.parametrize("algorithm", ["naive4", "gauss3"])
def test_complex_decomposition(rng, algorithm):
    """The paper's complex-float column via real GEMMs (incl. the
    3-multiply beyond-paper variant)."""
    a, b = _mats(rng, 96, 80, 64, "complex64")
    real_mm = lambda x, y: ops.matmul(x, y, backend="pallas_interpret")
    out = precision.complex_matmul(a, b, real_mm, algorithm=algorithm)
    ref = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-3)


def test_gemm_chokepoint_backends(rng):
    a = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    ref = np.asarray(matmul_ref(a, b))
    for backend in ("xla", "pallas_interpret", "naive_interpret"):
        out = gemm.matmul(a, b, backend=backend)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5,
                                   atol=1e-4, err_msg=backend)


def test_gemm_batched_and_vjp(rng):
    a = jnp.asarray(rng.normal(size=(3, 16, 24)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    out = gemm.matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=1e-5, atol=1e-4)

    def f(a_, b_):
        return jnp.sum(gemm.matmul(a_, b_, backend="pallas_interpret") ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    ga_ref, gb_ref = jax.grad(
        lambda a_, b_: jnp.sum((a_ @ b_) ** 2), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ref), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ref), rtol=1e-4,
                               atol=1e-3)


def test_elementwise_kernels(rng):
    from repro.kernels.elementwise import axpy, binary_op
    x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(binary_op(x, y, "add", interpret=True)),
        np.asarray(x + y), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(binary_op(x, y, "sub", interpret=True)),
        np.asarray(x - y), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(axpy(3.0, x, y, interpret=True)),
        np.asarray(3.0 * x + y), rtol=1e-5, atol=1e-5)
