"""Pin every assigned architecture dimension to the task sheet —
config drift fails loudly."""

import pytest

import repro.configs as C

# (name, family, L, d_model, H, Hkv, d_ff, vocab, extras)
ASSIGNED = [
    ("whisper-tiny", "encdec", 4, 384, 6, 6, 1536, 51865,
     dict(n_enc_layers=4, enc_ctx=1500, norm="ln", mlp="gelu",
          use_rope=False)),
    ("mixtral-8x22b", "moe", 56, 6144, 48, 8, 16384, 32768,
     dict(window=4096)),
    ("arctic-480b", "moe", 35, 7168, 56, 8, 4864, 32000, {}),
    ("qwen2-vl-2b", "vlm", 28, 1536, 12, 2, 8960, 151936,
     dict(mrope_sections=(16, 24, 24), qkv_bias=True)),
    ("qwen3-0.6b", "dense", 28, 1024, 16, 8, 3072, 151936,
     dict(qk_norm=True)),
    ("qwen1.5-32b", "dense", 64, 5120, 40, 40, 27392, 152064,
     dict(qkv_bias=True)),
    ("granite-20b", "dense", 52, 6144, 48, 1, 24576, 49152, {}),
    ("granite-3-8b", "dense", 40, 4096, 32, 8, 12800, 49155, {}),
    ("zamba2-1.2b", "hybrid", 36, 2048, 32, 32, 8192, 32000,
     dict(attn_every=6)),
    ("mamba2-2.7b", "ssm", 64, 2560, 1, 1, 0, 50280, {}),
]


@pytest.mark.parametrize("name,family,L,d,h,hkv,ff,vocab,extra", ASSIGNED)
def test_assigned_dims(name, family, L, d, h, hkv, ff, vocab, extra):
    cfg = C.get_config(name)
    assert cfg.family == family
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == hkv
    assert cfg.d_ff == ff
    assert cfg.vocab == vocab
    for k, v in extra.items():
        assert getattr(cfg, k) == v, (name, k)
    assert cfg.padded_vocab % cfg.vocab_pad_to == 0
    assert cfg.padded_vocab >= cfg.vocab


def test_moe_ssm_extras():
    mix = C.get_config("mixtral-8x22b").moe
    assert (mix.n_experts, mix.top_k) == (8, 2)
    arc = C.get_config("arctic-480b").moe
    assert (arc.n_experts, arc.top_k) == (128, 2)
    assert arc.dense_ff > 0                       # dense residual branch
    zam = C.get_config("zamba2-1.2b").ssm
    assert zam.d_state == 64
    mam = C.get_config("mamba2-2.7b").ssm
    assert mam.d_state == 128
    assert C.get_config("zamba2-1.2b").shared_attn_lora_rank > 0


def test_every_arch_has_reduced():
    for name in C.ARCH_NAMES:
        red = C.get_config(name, reduced=True)
        assert red.family == C.get_config(name).family
        assert red.d_model <= 128
        assert red.vocab <= 1024


def test_shape_cells():
    from repro.configs.base import SHAPES
    got = {(s.name, s.kind, s.seq_len, s.global_batch) for s in SHAPES}
    assert got == {
        ("train_4k", "train", 4096, 256),
        ("prefill_32k", "prefill", 32768, 32),
        ("decode_32k", "decode", 32768, 128),
        ("long_500k", "decode", 524288, 1),
    }


def test_long500k_applicability_table():
    """DESIGN §6: exactly mamba2/zamba2/mixtral run long_500k."""
    from repro.configs.base import get_shape
    from repro.launch import specs as S
    cell = get_shape("long_500k")
    runs = {n for n in C.ARCH_NAMES
            if S.applicable(C.get_config(n), cell)[0]}
    assert runs == {"mamba2-2.7b", "zamba2-1.2b", "mixtral-8x22b"}
