"""Fused-epilogue / dual-GEMM coverage: forward parity vs the unfused
XLA composition (bf16/f32, padded odd shapes), VJP parity vs jax.grad
of the reference, f64/complex routing back to the unfused path through
core.gemm, the epilogue-keyed tuner cache, and the matmul_tiled clamp
re-validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm
from repro.core.policy import Policy
from repro.core.blocking import BlockConfig
from repro.kernels import ops
from repro.kernels.matmul import EPILOGUES, matmul_tiled
from repro.kernels.ref import epilogue_ref, gated_matmul_ref, matmul_ref
from repro.tuning import cache as tcache

SHAPES = [
    (128, 128, 128),
    (100, 130, 50),      # ragged: exercises padding of every operand
    (256, 384, 512),
]


def _tol(dtype):
    return 1e-5 if dtype == "float32" else 2e-2


def _operands(rng, m, n, k, dtype):
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    bias = jnp.asarray(rng.normal(size=(n,)), dtype)
    r = jnp.asarray(rng.normal(size=(m, n)), dtype)
    return a, b, bias, r


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("epilogue", EPILOGUES)
def test_epilogue_matches_unfused(rng, m, n, k, dtype, epilogue):
    a, b, bias, r = _operands(rng, m, n, k, dtype)
    kw = {}
    if epilogue == "residual":
        kw["residual"] = r
    elif epilogue != "none":
        kw["bias"] = bias
    out = ops.matmul(a, b, backend="pallas_interpret", epilogue=epilogue,
                     **kw)
    ref = epilogue_ref(matmul_ref(a, b, out_dtype=jnp.float32), epilogue,
                       kw.get("bias"), kw.get("residual"))
    tol = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gated_matches_unfused(rng, m, n, k, dtype):
    a, wg, _, _ = _operands(rng, m, n, k, dtype)
    wu = jnp.asarray(rng.normal(size=(k, n)), dtype)
    out = ops.gated_matmul(a, wg, wu, backend="pallas_interpret")
    ref = gated_matmul_ref(a, wg, wu, out_dtype=jnp.float32)
    tol = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 8)


def test_dense_activation_forward_and_vjp(rng):
    x = jnp.asarray(rng.normal(size=(48, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(40, 56)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(56,)), jnp.float32)

    for act, f in (("gelu", jax.nn.gelu), ("silu", jax.nn.silu)):
        out = gemm.dense(x, w, b, activation=act,
                         backend="pallas_interpret")
        ref = f(x @ w + b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

        def loss(x_, w_, b_):
            return jnp.sum(gemm.dense(x_, w_, b_, activation=act,
                                      backend="pallas_interpret") ** 2)

        grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
        refs = jax.grad(
            lambda x_, w_, b_, f=f: jnp.sum(f(x_ @ w_ + b_) ** 2),
            argnums=(0, 1, 2))(x, w, b)
        for g, r in zip(grads, refs):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=1e-4, atol=1e-3, err_msg=act)


def test_dense_residual_forward_and_vjp(rng):
    x = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 48)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    out = gemm.dense(x, w, residual=r, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w + r),
                               rtol=1e-5, atol=1e-4)

    def loss(x_, w_, r_):
        return jnp.sum(gemm.dense(x_, w_, residual=r_,
                                  backend="pallas_interpret") ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, w, r)
    refs = jax.grad(lambda x_, w_, r_: jnp.sum((x_ @ w_ + r_) ** 2),
                    argnums=(0, 1, 2))(x, w, r)
    for g, ref in zip(grads, refs):
        np.testing.assert_allclose(np.asarray(g), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)


def test_dense_broadcast_residual_matches_xla(rng):
    """A residual that broadcasts but is not (m, n) cannot ride the
    fused flush — it must be added unfused, matching the xla backend."""
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 24)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(1, 24)), jnp.float32)
    fused = gemm.dense(x, w, residual=r, backend="pallas_interpret")
    ref = gemm.dense(x, w, residual=r, backend="xla")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_gated_mlp_vjp_and_batched(rng):
    x = jnp.asarray(rng.normal(size=(3, 16, 24)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(24, 32)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(24, 32)), jnp.float32)

    out = gemm.gated_mlp(x, wg, wu, backend="pallas_interpret")
    ref = jax.nn.silu(x @ wg) * (x @ wu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)

    def loss(x_, g_, u_):
        return jnp.sum(gemm.gated_mlp(x_, g_, u_,
                                      backend="pallas_interpret") ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, wg, wu)
    refs = jax.grad(
        lambda x_, g_, u_: jnp.sum((jax.nn.silu(x_ @ g_) * (x_ @ u_)) ** 2),
        argnums=(0, 1, 2))(x, wg, wu)
    for g, r in zip(grads, refs):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-3)

    # MoE-style expert banks: batched weights vmapped over the 2D path
    xb = jnp.asarray(rng.normal(size=(4, 8, 24)), jnp.float32)
    gb = jnp.asarray(rng.normal(size=(4, 24, 16)), jnp.float32)
    ub = jnp.asarray(rng.normal(size=(4, 24, 16)), jnp.float32)
    outb = gemm.gated_mlp(xb, gb, ub, backend="pallas_interpret")
    refb = jax.nn.silu(xb @ gb) * (xb @ ub)
    np.testing.assert_allclose(np.asarray(outb), np.asarray(refb),
                               rtol=1e-5, atol=1e-4)


def test_complex_falls_back_to_unfused(rng, monkeypatch):
    """complex64 must never reach the fused kernels: core.gemm routes it
    through the unfused composition (complex decomposition inside the
    plain chokepoint)."""
    def boom(*a, **kw):  # pragma: no cover - failure path
        raise AssertionError("fused kernel called with complex input")
    monkeypatch.setattr(ops, "gated_matmul", boom)
    a = jnp.asarray(rng.normal(size=(16, 12))
                    + 1j * rng.normal(size=(16, 12)), jnp.complex64)
    wg = jnp.asarray(rng.normal(size=(12, 8))
                     + 1j * rng.normal(size=(12, 8)), jnp.complex64)
    wu = jnp.asarray(rng.normal(size=(12, 8))
                     + 1j * rng.normal(size=(12, 8)), jnp.complex64)
    out = gemm.gated_mlp(a, wg, wu, backend="pallas_interpret")
    ref = jax.nn.silu(a @ wg) * (a @ wu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)
    # dense bias epilogue likewise stays unfused for complex
    b = jnp.asarray(rng.normal(size=(8,)), jnp.complex64)
    out = gemm.dense(a, wg, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ wg + b),
                               rtol=1e-4, atol=1e-3)


def test_f64_routes_unfused():
    """f64 has no MXU path: the fusibility gate must exclude it (the
    interpret-mode f64 end-to-end run lives in test_kernels_matmul's
    x64 subprocess)."""
    P = Policy.from_backend
    assert not gemm._fusible(jnp.float64, P("pallas"))
    assert not gemm._fusible(jnp.float64, P("pallas_interpret"))
    assert not gemm._fusible(jnp.complex64, P("tuned"))
    assert gemm._fusible(jnp.float32, P("pallas_interpret"))
    assert gemm._fusible(jnp.bfloat16, P("tuned"))
    assert not gemm._fusible(jnp.float32, P("xla"))
    assert not gemm._fusible(jnp.float32, P("naive"))
    # the policy toggle gates fusion too
    assert not gemm._fusible(
        jnp.float32, P("pallas_interpret").replace(fuse_epilogues=False))


def test_clamped_block_revalidates():
    """The old min(bm, m) clamp silently rewrote served configs; now a
    clamp that breaks divisibility is a clear ValueError."""
    a = jnp.zeros((100, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    with pytest.raises(ValueError, match="does not divide"):
        matmul_tiled(a, b, bm=64, bn=64, bk=64, interpret=True)


def test_bad_cached_block_falls_back(tmp_path, monkeypatch, rng):
    """A degenerate autotuner entry (corrupt cache) must fall back to
    the static chooser instead of crashing the tuned backend."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, path)
    tcache.reset_cache()
    c = tcache.get_cache()
    c.put_matmul(96, 96, 96, "float32", "pallas_interpret",
                 BlockConfig(0, 128, 128))
    c.put_gated(96, 96, 96, "float32", "pallas_interpret",
                BlockConfig(0, 128, 128))
    c.save()
    a = jnp.asarray(rng.normal(size=(96, 96)), jnp.float32)
    out = ops.matmul(a, a, backend="tuned_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, a)),
                               rtol=1e-4, atol=1e-3)
    out = ops.gated_matmul(a, a, a, backend="tuned_interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gated_matmul_ref(a, a, a)),
                               rtol=1e-4, atol=1e-3)
    tcache.reset_cache()


def test_tuned_serves_epilogue_and_gated_keys(tmp_path, monkeypatch, rng):
    """Epilogue variants and the gated kernel have their own cache keys;
    a planted non-default config must be served (hit counter) and stay
    correct."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, path)
    tcache.reset_cache()
    c = tcache.get_cache()
    c.put_matmul(128, 128, 128, "float32", "pallas_interpret",
                 BlockConfig(64, 128, 128), epilogue="bias_silu")
    c.put_gated(128, 128, 128, "float32", "pallas_interpret",
                BlockConfig(64, 128, 128))
    c.save()
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(128,)), jnp.float32)

    hits0 = c.hits
    out = ops.matmul(a, a, backend="tuned_interpret", epilogue="bias_silu",
                     bias=bias)
    assert c.hits == hits0 + 1
    ref = epilogue_ref(matmul_ref(a, a), "bias_silu", bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)
    # the epilogue key is distinct from the plain GEMM key
    assert c.get_matmul(128, 128, 128, "float32", "pallas_interpret") is None

    hits0 = c.hits
    out = ops.gated_matmul(a, a, a, backend="tuned_interpret")
    assert c.hits == hits0 + 1
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gated_matmul_ref(a, a, a)),
                               rtol=1e-4, atol=1e-3)
    tcache.reset_cache()


def test_model_gemm_shapes_cover_fused_ops():
    from repro.configs import get_config
    from repro.tuning import autotuner
    cfg = get_config("qwen3-0.6b", reduced=True)
    entries = autotuner.model_gemm_shapes(cfg, 2, 16)
    ops_seen = {e[0] for e in entries}
    assert "gated" in ops_seen          # SwiGLU FFN is served fused
    assert any(e[0] == "matmul" and e[4] == "residual" for e in entries)
    bwd = autotuner.model_gemm_shapes(cfg, 2, 16, backward=True)
    assert len(bwd) > len(entries)
    # cotangent GEMMs are plain (the fused VJPs recurse unfused)
    assert all(e[4] == "none" for e in set(bwd) - set(entries)
               if e[0] == "matmul")
