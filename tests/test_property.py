"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.core import blocking, intensity, precision
from repro.core.hw import TPU_V5E
from repro.core.policy import Policy
from repro.distributed import compression
from repro.kernels import ops, registry
from repro.kernels import matmul as mm_kernels
from repro.kernels import ref as kref
from repro.kernels.ref import matmul_ref
from repro.models import moe as MOE
from repro.models.layers import apply_rope, default_positions
from repro.models.ssm import _segsum

_settings = settings(max_examples=25, deadline=None)


@given(m=st.integers(8, 512), n=st.integers(8, 512), k=st.integers(8, 2048),
       itemsize=st.sampled_from([2, 4]))
@_settings
def test_block_config_always_fits_vmem(m, n, k, itemsize):
    """The paper's shared-memory-budget invariant, for every shape: the
    chosen tile set must fit the VMEM budget and stay MXU-aligned."""
    cfg = blocking.choose_block_config(m, n, k, itemsize)
    assert cfg.vmem_bytes(itemsize) <= TPU_V5E.vmem_bytes * 0.5 + 1
    assert cfg.bn % TPU_V5E.lane == 0 or cfg.bn >= n
    assert cfg.bm % TPU_V5E.sublane(itemsize) == 0 or cfg.bm >= m


@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300))
@_settings
def test_tiled_traffic_never_exceeds_naive(m, n, k):
    """Blocking can only reduce HBM traffic (claim C1/C2)."""
    cfg = blocking.choose_block_config(m, n, k, 4)
    tiled = blocking.hbm_traffic_bytes(m, n, k, cfg, 4)
    naive = blocking.naive_traffic_bytes(m, n, k, 4)
    assert tiled <= naive


@given(st.integers(16, 512))
@_settings
def test_add_is_memory_bound_matmul_depends(n):
    """Claim C3: add is always memory-bound; square matmul crosses to
    compute-bound once n exceeds the machine balance point."""
    add = intensity.classify(intensity.add_profile(n, n, 4), itemsize=4)
    assert add["bound"] == "memory"
    mm = intensity.classify(intensity.matmul_profile(n, n, n, 2), itemsize=2)
    balance = intensity.machine_balance(itemsize=2)
    ai = mm["arithmetic_intensity"]
    assert (mm["bound"] == "compute") == (ai >= balance)


@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_matmul_padding_path(m, k, n, seed):
    """ops.matmul pads ragged shapes; result must equal the oracle."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------------------
# Cross-backend differential harness: EVERY backend registered for an op
# in kernels.registry is run against the pure-jnp oracle on
# hypothesis-generated (shape, dtype, epilogue) tuples. A new backend
# (a single @register_op call) is conformance-tested here for free —
# including matmul_q, whose weights are drawn through the real
# quantizer so the oracle and the kernels see the same int8 grid.
# ----------------------------------------------------------------------

#: max|err| allowed as a fraction of max|ref| — scaled by the dtype's
#: accumulation/rounding granularity (bf16 epsilon is 2^-8).
_DIFF_TOL = {"float32": 1e-4, "bfloat16": 6e-2}


def _diff_operands(rng, m, n, k, dtype, epilogue):
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    bias = residual = None
    if epilogue == "residual":
        residual = jnp.asarray(rng.normal(size=(m, n)), dtype)
    elif epilogue != "none":
        bias = jnp.asarray(rng.normal(size=(n,)), dtype)
    return a, b, bias, residual


def _assert_backend_close(backend, out, ref_f32, dtype):
    tol = _DIFF_TOL[dtype]
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref_f32)))
    bound = tol * max(float(jnp.max(jnp.abs(ref_f32))), 1.0)
    assert err <= bound, (backend, err, bound)


@given(m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(1, 40),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       epilogue=st.sampled_from(mm_kernels.EPILOGUES),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_matmul_backends_match_reference(m, n, k, dtype, epilogue, seed):
    rng = np.random.default_rng(seed)
    a, b, bias, residual = _diff_operands(rng, m, n, k, dtype, epilogue)
    ref = kref.epilogue_ref(kref.matmul_ref(a, b, out_dtype=jnp.float32),
                            epilogue, bias, residual)
    for backend in registry.registered_backends("matmul"):
        out = ops.matmul(a, b, policy=Policy(backend=backend, interpret=True),
                         epilogue=epilogue, bias=bias, residual=residual)
        assert out.dtype == jnp.dtype(dtype), backend
        _assert_backend_close(backend, out, ref, dtype)


@given(m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(1, 40),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       epilogue=st.sampled_from(mm_kernels.EPILOGUES),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_matmul_q_backends_match_reference(m, n, k, dtype, epilogue, seed):
    rng = np.random.default_rng(seed)
    a, b, bias, residual = _diff_operands(rng, m, n, k, dtype, epilogue)
    wq, scale = precision.quantize_int8(b)
    ref = kref.epilogue_ref(
        kref.matmul_q_ref(a, wq, scale, out_dtype=jnp.float32),
        epilogue, bias, residual)
    for backend in registry.registered_backends("matmul_q"):
        out = ops.matmul_q(a, wq, scale,
                           policy=Policy(backend=backend, interpret=True),
                           epilogue=epilogue, bias=bias, residual=residual)
        assert out.dtype == jnp.dtype(dtype), backend
        _assert_backend_close(backend, out, ref, dtype)


@given(m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(1, 40),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_gated_matmul_backends_match_reference(m, n, k, dtype, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    wg = jnp.asarray(rng.normal(size=(k, n)), dtype)
    wu = jnp.asarray(rng.normal(size=(k, n)), dtype)
    ref = kref.gated_matmul_ref(a, wg, wu,
                                out_dtype=jnp.float32).astype(jnp.float32)
    for backend in registry.registered_backends("gated_matmul"):
        out = ops.gated_matmul(
            a, wg, wu, policy=Policy(backend=backend, interpret=True))
        assert out.dtype == jnp.dtype(dtype), backend
        _assert_backend_close(backend, out, ref, dtype)


# Attention ops: shapes are drawn from the kernels' divisibility lattice
# (tq % bq == 0, tk % bk == 0 after clamping) so every registered
# backend — pallas included — runs its real tiled path, not a fallback.
_ATTN_SEQ = st.sampled_from([16, 32, 64])
_ATTN_D = st.sampled_from([16, 32])
_ATTN_GROUP = st.sampled_from([1, 2, 4])
_ATTN_WINDOW = st.sampled_from([None, 8, 24])


def _attn_operands(rng, tq, tk, d, group, dtype):
    h = 4
    hkv = h // group
    q = jnp.asarray(rng.normal(size=(2, tq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(2, tk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(2, tk, hkv, d)), dtype)
    return q, k, v


@given(tq=_ATTN_SEQ, tk=_ATTN_SEQ, d=_ATTN_D, group=_ATTN_GROUP,
       causal=st.booleans(), window=_ATTN_WINDOW,
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_flash_attention_backends_match_reference(tq, tk, d, group, causal,
                                                  window, dtype, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _attn_operands(rng, tq, tk, d, group, dtype)
    ref = kref.attention_ref(q, k, v, causal=causal,
                             window=window).astype(jnp.float32)
    for backend in registry.registered_backends("flash_attention"):
        out = ops.flash_attention(
            q, k, v, causal=causal, window=window,
            policy=Policy(backend=backend, interpret=True))
        assert out.dtype == jnp.dtype(dtype), backend
        _assert_backend_close(backend, out, ref, dtype)


@given(tk=st.sampled_from([32, 64, 128]), d=_ATTN_D, group=_ATTN_GROUP,
       window=_ATTN_WINDOW, dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_flash_decode_backends_match_reference(tk, d, group, window, dtype,
                                               seed):
    rng = np.random.default_rng(seed)
    q, k, v = _attn_operands(rng, 1, tk, d, group, dtype)
    # ragged per-slot depths, one mid-stream
    pos = jnp.asarray([tk - 1, int(rng.integers(0, tk))], jnp.int32)
    ref, _ = kref.attention_fwd_ref(q, k, v, causal=True, window=window,
                                    q_offset=pos)
    ref = ref.astype(jnp.float32)
    for backend in registry.registered_backends("flash_decode"):
        out = ops.flash_decode(
            q, k, v, pos=pos, window=window,
            policy=Policy(backend=backend, interpret=True))
        assert out.dtype == jnp.dtype(dtype), backend
        _assert_backend_close(backend, out, ref, dtype)


@given(ps=st.sampled_from([8, 16]), pp=st.sampled_from([2, 4]),
       d=_ATTN_D, group=_ATTN_GROUP, window=_ATTN_WINDOW,
       quant=st.booleans(), seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_flash_decode_paged_backends_match_reference(ps, pp, d, group,
                                                     window, quant, seed):
    """Paged decode conformance: every registered backend must match the
    gather+softmax oracle on a scattered page table with shared pages,
    an unmapped (-1) tail, ragged per-slot depths and — when quant is
    set — int8 pools with per-(position, head) f32 scale planes."""
    rng = np.random.default_rng(seed)
    B, hkv = 2, 2
    h = hkv * group
    n_pages = B * pp + 1                     # one page never mapped
    q = jnp.asarray(rng.normal(size=(B, 1, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    table = jnp.asarray(rng.permutation(n_pages)[:B * pp].reshape(B, pp),
                        jnp.int32)
    table = table.at[1, -1].set(-1)          # slot 1: last page unmapped
    pos = jnp.asarray([ps * pp - 1,
                       int(rng.integers(0, ps * (pp - 1)))], jnp.int32)
    ks = vs = None
    if quant:
        kp, ks = precision.quantize_kv(kp)
        vp, vs = precision.quantize_kv(vp)
        ks = ks.transpose(0, 2, 1)           # (P, ps, hkv) -> (P, hkv, ps)
        vs = vs.transpose(0, 2, 1)
    ref = kref.flash_decode_paged_ref(q, kp, vp, table, pos=pos,
                                      window=window, ks=ks, vs=vs)
    ref = ref.astype(jnp.float32)
    for backend in registry.registered_backends("flash_decode_paged"):
        out = ops.flash_decode_paged(
            q, kp, vp, table, pos=pos, window=window, ks=ks, vs=vs,
            policy=Policy(backend=backend, interpret=True))
        assert out.dtype == q.dtype, backend
        _assert_backend_close(backend, out, ref, "float32")


@given(tq=_ATTN_SEQ, tk=_ATTN_SEQ, d=_ATTN_D, group=_ATTN_GROUP,
       causal=st.booleans(), window=_ATTN_WINDOW,
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_flash_bwd_backends_match_reference(tq, tk, d, group, causal,
                                            window, dtype, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _attn_operands(rng, tq, tk, d, group, dtype)
    do = jnp.asarray(rng.normal(size=q.shape), dtype)
    o, lse = kref.attention_fwd_ref(q, k, v, causal=causal, window=window)
    # independent oracle: differentiate through the dense reference
    _, vjp = jax.vjp(lambda q_, k_, v_: kref.attention_ref(
        q_, k_, v_, causal=causal, window=window), q, k, v)
    refs = [g.astype(jnp.float32) for g in vjp(do)]
    for backend in registry.registered_backends("flash_attention_bwd"):
        grads = ops.flash_attention_bwd(
            q, k, v, o, do, lse, causal=causal, window=window,
            policy=Policy(backend=backend, interpret=True))
        for name, g, r in zip(("dq", "dk", "dv"), grads, refs):
            _assert_backend_close(f"{backend}:{name}", g.astype(jnp.float32),
                                  r, dtype)


@given(chunk=st.sampled_from([8, 16]), nc=st.sampled_from([1, 2, 4]),
       h=st.sampled_from([2, 4]), group=st.sampled_from([1, 2]),
       n=st.sampled_from([8, 16]), p=st.sampled_from([8, 16]),
       carried=st.booleans(),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_ssd_backends_match_reference(chunk, nc, h, group, n, p, carried,
                                      dtype, seed):
    """Every registered SSD backend vs the sequential per-token scan
    oracle — the chunked algebra (intra-chunk masks + inter-chunk
    recurrence) must be invisible, carried init_state and bf16 inputs
    included. States are compared at f32 tolerance regardless of input
    dtype: the f32-carry contract this PR pinned."""
    rng = np.random.default_rng(seed)
    l, g = chunk * nc, h // group
    x = jnp.asarray(rng.normal(size=(2, l, h, p)), dtype)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, size=(2, l, h)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, l, g, n)), dtype)
    c = jnp.asarray(rng.normal(size=(2, l, g, n)), dtype)
    s0 = (jnp.asarray(rng.normal(size=(2, h, p, n)), jnp.float32)
          if carried else None)
    ref_y, ref_s = kref.ssd_ref(x, a, b, c, chunk, init_state=s0)
    ref_y = ref_y.astype(jnp.float32)
    for backend in registry.registered_backends("ssd"):
        y, s = ops.ssd(x, a, b, c, chunk, init_state=s0,
                       policy=Policy(backend=backend, interpret=True))
        assert y.dtype == jnp.dtype(dtype), backend
        assert s.dtype == jnp.float32, backend
        _assert_backend_close(backend, y, ref_y, dtype)
        _assert_backend_close(f"{backend}:state", s, ref_s, "float32")


@given(seed=st.integers(0, 2**31), scale=st.floats(0.01, 10.0))
@settings(max_examples=15, deadline=None)
def test_compression_error_feedback_bounded(seed, scale):
    """EF invariant: per-tensor residual is bounded by the quantisation
    step (|err| <= scale_q = max|g+e| / 127)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)) * scale, jnp.float32)}
    ef = compression.init_ef(g)
    for _ in range(3):
        q, ef = compression.compress_grads(g, ef)
        step = float(jnp.max(jnp.abs(g["w"] + 0))) / 127.0
        assert float(jnp.max(jnp.abs(ef.error["w"]))) <= 2 * step + 1e-6


@given(seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_mrope_degenerates_to_rope_on_text(seed):
    """Qwen2-VL M-RoPE with t=h=w equals standard RoPE (spec property)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)), jnp.float32)
    pos = default_positions(2, 16)
    plain = apply_rope(x, pos, 10_000.0)
    pos3 = jnp.stack([pos, pos, pos], axis=-1)
    mrope = apply_rope(x, pos3, 10_000.0, mrope_sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(mrope),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31), q=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_segsum_telescopes(seed, q):
    """SSD decay identity: S[i,j] = cs[i] - cs[j] for i >= j."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(-rng.uniform(0.01, 1.0, size=(q,)), jnp.float32)
    s = np.asarray(_segsum(a))
    cs = np.cumsum(np.asarray(a))
    for i in range(q):
        for j in range(q):
            if j <= i:
                np.testing.assert_allclose(s[i, j], cs[i] - cs[j],
                                           rtol=1e-5, atol=1e-5)
            else:
                assert s[i, j] == -np.inf


@given(seed=st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_moe_combine_is_convex(seed):
    """Router invariant: with top-k renormalised gates, an MoE whose
    experts all compute the identity returns (approximately) the input
    scaled by the kept-gate mass — dropped tokens lose exactly their
    dropped gate fraction."""
    rng = np.random.default_rng(seed)
    cfg = C.get_config("mixtral-8x22b", reduced=True)
    p = MOE.moe_init(jax.random.PRNGKey(seed % 1000), cfg)
    x = jnp.asarray(rng.normal(size=(1, 32, cfg.d_model)), jnp.float32)
    _, aux = MOE.moe_apply(p, x, cfg)
    assert 0.0 <= float(aux["moe_dropped_frac"]) <= 1.0
    assert float(aux["moe_lb_loss"]) >= 0.0
