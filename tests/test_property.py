"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.core import blocking, intensity
from repro.core.hw import TPU_V5E
from repro.distributed import compression
from repro.kernels import ops
from repro.kernels.ref import matmul_ref
from repro.models import moe as MOE
from repro.models.layers import apply_rope, default_positions
from repro.models.ssm import _segsum

_settings = settings(max_examples=25, deadline=None)


@given(m=st.integers(8, 512), n=st.integers(8, 512), k=st.integers(8, 2048),
       itemsize=st.sampled_from([2, 4]))
@_settings
def test_block_config_always_fits_vmem(m, n, k, itemsize):
    """The paper's shared-memory-budget invariant, for every shape: the
    chosen tile set must fit the VMEM budget and stay MXU-aligned."""
    cfg = blocking.choose_block_config(m, n, k, itemsize)
    assert cfg.vmem_bytes(itemsize) <= TPU_V5E.vmem_bytes * 0.5 + 1
    assert cfg.bn % TPU_V5E.lane == 0 or cfg.bn >= n
    assert cfg.bm % TPU_V5E.sublane(itemsize) == 0 or cfg.bm >= m


@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300))
@_settings
def test_tiled_traffic_never_exceeds_naive(m, n, k):
    """Blocking can only reduce HBM traffic (claim C1/C2)."""
    cfg = blocking.choose_block_config(m, n, k, 4)
    tiled = blocking.hbm_traffic_bytes(m, n, k, cfg, 4)
    naive = blocking.naive_traffic_bytes(m, n, k, 4)
    assert tiled <= naive


@given(st.integers(16, 512))
@_settings
def test_add_is_memory_bound_matmul_depends(n):
    """Claim C3: add is always memory-bound; square matmul crosses to
    compute-bound once n exceeds the machine balance point."""
    add = intensity.classify(intensity.add_profile(n, n, 4), itemsize=4)
    assert add["bound"] == "memory"
    mm = intensity.classify(intensity.matmul_profile(n, n, n, 2), itemsize=2)
    balance = intensity.machine_balance(itemsize=2)
    ai = mm["arithmetic_intensity"]
    assert (mm["bound"] == "compute") == (ai >= balance)


@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_matmul_padding_path(m, k, n, seed):
    """ops.matmul pads ragged shapes; result must equal the oracle."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-3)


@given(seed=st.integers(0, 2**31), scale=st.floats(0.01, 10.0))
@settings(max_examples=15, deadline=None)
def test_compression_error_feedback_bounded(seed, scale):
    """EF invariant: per-tensor residual is bounded by the quantisation
    step (|err| <= scale_q = max|g+e| / 127)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)) * scale, jnp.float32)}
    ef = compression.init_ef(g)
    for _ in range(3):
        q, ef = compression.compress_grads(g, ef)
        step = float(jnp.max(jnp.abs(g["w"] + 0))) / 127.0
        assert float(jnp.max(jnp.abs(ef.error["w"]))) <= 2 * step + 1e-6


@given(seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_mrope_degenerates_to_rope_on_text(seed):
    """Qwen2-VL M-RoPE with t=h=w equals standard RoPE (spec property)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)), jnp.float32)
    pos = default_positions(2, 16)
    plain = apply_rope(x, pos, 10_000.0)
    pos3 = jnp.stack([pos, pos, pos], axis=-1)
    mrope = apply_rope(x, pos3, 10_000.0, mrope_sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(mrope),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31), q=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_segsum_telescopes(seed, q):
    """SSD decay identity: S[i,j] = cs[i] - cs[j] for i >= j."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(-rng.uniform(0.01, 1.0, size=(q,)), jnp.float32)
    s = np.asarray(_segsum(a))
    cs = np.cumsum(np.asarray(a))
    for i in range(q):
        for j in range(q):
            if j <= i:
                np.testing.assert_allclose(s[i, j], cs[i] - cs[j],
                                           rtol=1e-5, atol=1e-5)
            else:
                assert s[i, j] == -np.inf


@given(seed=st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_moe_combine_is_convex(seed):
    """Router invariant: with top-k renormalised gates, an MoE whose
    experts all compute the identity returns (approximately) the input
    scaled by the kept-gate mass — dropped tokens lose exactly their
    dropped gate fraction."""
    rng = np.random.default_rng(seed)
    cfg = C.get_config("mixtral-8x22b", reduced=True)
    p = MOE.moe_init(jax.random.PRNGKey(seed % 1000), cfg)
    x = jnp.asarray(rng.normal(size=(1, 32, cfg.d_model)), jnp.float32)
    _, aux = MOE.moe_apply(p, x, cfg)
    assert 0.0 <= float(aux["moe_dropped_frac"]) <= 1.0
    assert float(aux["moe_lb_loss"]) >= 0.0
