"""SSD Pallas kernel (interpret) vs the jnp oracle (models.ssm), across
chunk sizes, head counts and group configurations."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_pallas
from repro.models.ssm import ssd_chunked


@pytest.mark.parametrize("chunk", [16, 64])
@pytest.mark.parametrize("h,g", [(4, 1), (4, 2), (8, 8)])
def test_ssd_pallas_matches_oracle(rng, chunk, h, g):
    B, L, P, N = 2, 128, 16, 32
    x = jnp.asarray(rng.normal(size=(B, L, h, P)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, size=(B, L, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, g, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, g, N)), jnp.float32)
    y_ref, s_ref = ssd_chunked(x, a, bm, cm, chunk)
    y_k, s_k = ssd_pallas(x, a, bm, cm, chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_pallas_long_decay(rng):
    """Numerical stability: strong decays (long chunks) must not NaN."""
    B, L, H, P, G, N = 1, 256, 2, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 3.0, size=(B, L, H)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    y, s = ssd_pallas(x, a, bm, cm, 128, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
