"""SSD Pallas kernel (interpret) vs the jnp oracle (models.ssm), across
chunk sizes, head counts, group configurations, dtypes, carried state
and the core-level custom VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ssd as core_ssd
from repro.core.policy import Policy
from repro.kernels.ssd import ssd_pallas
from repro.models.ssm import ssd_chunked


@pytest.mark.parametrize("chunk", [16, 64])
@pytest.mark.parametrize("h,g", [(4, 1), (4, 2), (8, 8)])
def test_ssd_pallas_matches_oracle(rng, chunk, h, g):
    B, L, P, N = 2, 128, 16, 32
    x = jnp.asarray(rng.normal(size=(B, L, h, P)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, size=(B, L, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, g, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, g, N)), jnp.float32)
    y_ref, s_ref = ssd_chunked(x, a, bm, cm, chunk)
    y_k, s_k = ssd_pallas(x, a, bm, cm, chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def _operands(rng, B=2, L=64, H=4, G=2, P=16, N=16, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), dtype)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, size=(B, L, H)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, G, N)), dtype)
    cm = jnp.asarray(rng.normal(size=(B, L, G, N)), dtype)
    return x, a, bm, cm


def test_ssd_pallas_init_state_matches_oracle(rng):
    """The bug this PR fixed: ssd_pallas silently DROPPED init_state.
    A carried state must seed the inter-chunk scan on both backends."""
    x, a, bm, cm = _operands(rng)
    s0 = jnp.asarray(rng.normal(size=(2, 4, 16, 16)), jnp.float32)
    y_ref, s_ref = ssd_chunked(x, a, bm, cm, 16, init_state=s0)
    y_k, s_k = ssd_pallas(x, a, bm, cm, 16, init_state=s0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
    # and it must actually CHANGE the answer vs a zero state
    y0, _ = ssd_pallas(x, a, bm, cm, 16, interpret=True)
    assert float(jnp.max(jnp.abs(y_k - y0))) > 1e-3


def test_ssd_pallas_carried_state_split_prefill_bitwise(rng):
    """Chunked prefill: running the second half from the first half's
    final state is bitwise-identical to one full pass WITHIN the pallas
    backend — same kernel, same accumulation order, same f32 carry, so
    nothing may drift when the serving engine splits a prompt."""
    x, a, bm, cm = _operands(rng, L=64)
    y_full, s_full = ssd_pallas(x, a, bm, cm, 16, interpret=True)
    y1, s1 = ssd_pallas(x[:, :32], a[:, :32], bm[:, :32], cm[:, :32], 16,
                        interpret=True)
    y2, s2 = ssd_pallas(x[:, 32:], a[:, 32:], bm[:, 32:], cm[:, 32:], 16,
                        init_state=s1, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_full[:, :32]),
                                  np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y_full[:, 32:]),
                                  np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(s_full), np.asarray(s2))


def test_ssd_bf16_state_carried_f32(rng):
    """The bug this PR fixed: the oracle seeded s0 with x.dtype while
    the kernel accumulates f32. bf16 inputs must yield f32 states equal
    across backends to f32-roundoff, not bf16-roundoff."""
    x, a, bm, cm = _operands(rng, dtype=jnp.bfloat16)
    y_ref, s_ref = ssd_chunked(x, a, bm, cm, 16)
    y_k, s_k = ssd_pallas(x, a, bm, cm, 16, interpret=True)
    assert s_ref.dtype == jnp.float32
    assert s_k.dtype == jnp.float32
    assert y_ref.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_ref, np.float32),
        rtol=6e-2, atol=6e-2)


def test_ssd_execution_chunk_invariance(rng):
    """Chunking is algebraically exact: the execution chunk is a pure
    perf knob, so every (q, bp) candidate computes the same function —
    the property that makes the autotuner's sweep sound."""
    x, a, bm, cm = _operands(rng)
    y_ref, s_ref = ssd_chunked(x, a, bm, cm, 64)
    for q, bp in ((64, 16), (32, 8), (16, 16), (8, 4)):
        y, s = ssd_pallas(x, a, bm, cm, q, block_p=bp, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"q={q}, bp={bp}")
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"q={q}, bp={bp}")


def test_core_ssd_vjp_matches_unfused(rng):
    """core.ssd under a pallas policy trains: its custom VJP must match
    jax.grad through the unfused ssd_chunked composition."""
    x, a, bm, cm = _operands(rng)
    s0 = jnp.asarray(rng.normal(size=(2, 4, 16, 16)), jnp.float32)
    pol = Policy(backend="pallas", interpret=True)

    def fused(x_, a_, b_, c_, s_):
        y, s = core_ssd.ssd(x_, a_, b_, c_, 16, init_state=s_, policy=pol)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    def unfused(x_, a_, b_, c_, s_):
        y, s = ssd_chunked(x_, a_, b_, c_, 16, init_state=s_)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    grads = jax.grad(fused, argnums=(0, 1, 2, 3, 4))(x, a, bm, cm, s0)
    refs = jax.grad(unfused, argnums=(0, 1, 2, 3, 4))(x, a, bm, cm, s0)
    for gi, ri in zip(grads, refs):
        scale = max(float(jnp.max(jnp.abs(ri))), 1.0)
        np.testing.assert_allclose(np.asarray(gi), np.asarray(ri),
                                   rtol=1e-4, atol=1e-3 * scale)


def test_core_ssd_grad_finite_strong_decay(rng):
    """Gradients through the masked log-space exp (the unmasked-exp bug
    this PR fixed would overflow here) stay finite under strong decay."""
    B, L, H, P, G, N = 1, 64, 2, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 3.0, size=(B, L, H)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    pol = Policy(backend="pallas", interpret=True)

    def loss(x_, a_):
        y, s = core_ssd.ssd(x_, a_, bm, cm, 32, policy=pol)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    gx, ga = jax.grad(loss, argnums=(0, 1))(x, a)
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(ga)).all()


def test_ssd_pallas_long_decay(rng):
    """Numerical stability: strong decays (long chunks) must not NaN."""
    B, L, H, P, G, N = 1, 256, 2, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 3.0, size=(B, L, H)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    y, s = ssd_pallas(x, a, bm, cm, 128, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
