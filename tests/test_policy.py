"""Policy semantics: scope nesting, jit static-arg hashability,
deprecation shims, VJP policy inheritance, interpret unification, and
registry validation errors — the contracts ISSUE 4 pins."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm
from repro.core import policy as pol_mod
from repro.core.policy import Policy, current_policy, set_default_policy
from repro.kernels import ops, registry


@pytest.fixture
def a32():
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)


@pytest.fixture(autouse=True)
def _clean_ambient():
    """Each test starts from the built-in xla default."""
    set_default_policy(None)
    yield
    set_default_policy(None)


# ----------------------------------------------------------------------
# scope nesting / restoration
# ----------------------------------------------------------------------

def test_scope_nesting_and_restoration():
    base = current_policy()
    p1 = Policy(backend="pallas", interpret=True)
    p2 = Policy(backend="naive", interpret=True)
    with p1.scope():
        assert current_policy() is p1
        with p2.scope():
            assert current_policy() is p2
        assert current_policy() is p1
    assert current_policy() == base


def test_scope_restores_on_exception():
    p1 = Policy(backend="pallas", interpret=True)
    with pytest.raises(RuntimeError):
        with p1.scope():
            raise RuntimeError("boom")
    assert current_policy().backend == "xla"


def test_set_default_policy_vs_scope_precedence():
    default = Policy(backend="naive", interpret=True)
    set_default_policy(default)
    assert current_policy() is default
    inner = Policy(backend="pallas", interpret=True)
    with inner.scope():
        assert current_policy() is inner
    assert current_policy() is default
    set_default_policy(None)
    assert current_policy().backend == "xla"


def test_env_var_override(monkeypatch):
    monkeypatch.setenv(pol_mod.ENV_VAR,
                       "backend=pallas,interpret=true,autotune=cached")
    p = current_policy()
    assert (p.backend, p.interpret, p.autotune) == ("pallas", True, "cached")
    # legacy spelling parses too
    monkeypatch.setenv(pol_mod.ENV_VAR, "tuned_interpret")
    p = current_policy()
    assert (p.backend, p.interpret, p.autotune) == ("pallas", True, "cached")
    # explicit default outranks the env var
    set_default_policy(Policy())
    assert current_policy().backend == "xla"


def test_fingerprint_roundtrip():
    p = Policy(backend="pallas", interpret=True, autotune="cached",
               fuse_epilogues=False, out_dtype="bfloat16")
    assert Policy.parse(p.fingerprint()) == p
    assert Policy.parse(Policy().fingerprint()) == Policy()


# ----------------------------------------------------------------------
# hashability / jit static-arg behaviour
# ----------------------------------------------------------------------

def test_policy_hashable_and_jit_static(a32):
    traces = []
    f = jax.jit(lambda x, policy: (traces.append(policy),
                                   gemm.matmul(x, x, policy=policy))[1],
                static_argnames=("policy",))
    p = Policy(backend="pallas", interpret=True)
    y1 = f(a32, policy=p)
    n = len(traces)
    # identical policy (equal, fresh instance): no retrace
    f(a32, policy=Policy(backend="pallas", interpret=True))
    assert len(traces) == n
    # changed policy: exactly one new trace
    f(a32, policy=Policy(backend="naive", interpret=True))
    assert len(traces) == n + 1
    np.testing.assert_allclose(
        y1, gemm.matmul(a32, a32, policy=Policy()), rtol=1e-5)


def test_policy_as_nondiff_vjp_arg(a32):
    p = Policy(backend="pallas", interpret=True)
    g = jax.grad(lambda x: jnp.sum(gemm.matmul(x, a32, policy=p) ** 2))(a32)
    g_ref = jax.grad(lambda x: jnp.sum(gemm.matmul(x, a32) ** 2))(a32)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4)


# ----------------------------------------------------------------------
# interpret unification: one source of truth
# ----------------------------------------------------------------------

def test_resolved_interpret_auto_off_tpu():
    # this suite runs on CPU: interpret=None must NEVER mean "compile"
    assert jax.devices()[0].platform != "tpu"
    assert Policy(backend="pallas").resolved_interpret is True
    assert Policy(backend="pallas", interpret=False).resolved_interpret \
        is False


def test_pallas_scope_never_silently_compiles(a32, monkeypatch):
    """Regression: under an interpret=True scope every Pallas kernel
    call — matmul, gated, flash, elementwise — must receive
    interpret=True (no per-op suffix-sniffing left to disagree)."""
    seen = {}
    from repro.kernels import elementwise as ew
    from repro.kernels import flash_attention as fa
    from repro.kernels import matmul as mm

    def spy(name, fn):
        def wrapped(*args, **kw):
            seen.setdefault(name, []).append(kw.get("interpret"))
            return fn(*args, **kw)
        return wrapped

    monkeypatch.setattr(mm, "matmul_tiled", spy("tiled", mm.matmul_tiled))
    monkeypatch.setattr(mm, "gated_matmul_tiled",
                        spy("gated", mm.gated_matmul_tiled))
    monkeypatch.setattr(fa, "flash_attention",
                        spy("flash", fa.flash_attention))
    monkeypatch.setattr(ew, "binary_op", spy("binary", ew.binary_op))

    q = jnp.zeros((1, 8, 2, 16), jnp.float32)
    with Policy(backend="pallas", interpret=True).scope():
        ops.matmul(a32, a32)
        ops.gated_matmul(a32, a32, a32)
        ops.flash_attention(q, q, q, causal=True, bq=8, bk=8)
        ops.add(a32, a32)
    assert set(seen) == {"tiled", "gated", "flash", "binary"}
    for name, flags in seen.items():
        assert flags == [True], (name, flags)


def test_explicit_interpret_overrides_policy(a32, monkeypatch):
    from repro.kernels import elementwise as ew
    flags = []
    real = ew.binary_op
    monkeypatch.setattr(
        ew, "binary_op",
        lambda *a, **kw: (flags.append(kw["interpret"]), real(*a, **kw))[1])
    # scope says COMPILE (interpret=False); the explicit kwarg must win —
    # were the override dropped, this would attempt (and fail) a TPU
    # compile on this CPU host with interpret=False.
    with Policy(backend="pallas", interpret=False).scope():
        ops.add(a32, a32, interpret=True)
    assert flags == [True]


# ----------------------------------------------------------------------
# VJP paths inherit the ambient policy
# ----------------------------------------------------------------------

def test_vjp_inherits_ambient_policy(a32, monkeypatch):
    from repro.kernels import matmul as mm
    calls = []
    real = mm.matmul_tiled
    monkeypatch.setattr(
        mm, "matmul_tiled",
        lambda *a, **kw: (calls.append(kw["interpret"]), real(*a, **kw))[1])
    with Policy(backend="pallas", interpret=True).scope():
        jax.grad(lambda x: jnp.sum(gemm.matmul(x, a32) ** 2))(a32)
    # forward + da + db all ran the tiled kernel, all interpreted
    assert len(calls) >= 3 and all(calls)


# ----------------------------------------------------------------------
# deprecation shims
# ----------------------------------------------------------------------

def test_attention_follows_ambient_policy_with_deprecation(a32):
    """The old carve-out (attention silently pinned to xla unless given
    an explicit policy) is gone: under an ambient pallas scope the
    kernel path runs, announced by a one-time deprecation warning."""
    from repro.models.attention import attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    pol = Policy(backend="pallas", interpret=True)
    explicit = attention(q, q, q, causal=True, window=None, chunk=32,
                         policy=pol)
    pol_mod.reset_deprecation_warnings()
    with pol.scope():
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ambient = attention(q, q, q, causal=True, window=None, chunk=32)
            again = attention(q, q, q, causal=True, window=None, chunk=32)
    msgs = [x for x in w if issubclass(x.category, DeprecationWarning)
            and "ambient" in str(x.message)]
    assert len(msgs) == 1, "carve-out removal must warn exactly once"
    np.testing.assert_array_equal(np.asarray(ambient), np.asarray(explicit))
    np.testing.assert_array_equal(np.asarray(again), np.asarray(explicit))


def test_attention_explicit_xla_policy_stays_chunked(a32):
    """Policy(backend='xla') — explicit or ambient-default — keeps the
    chunked composition: bitwise equality pins the same code path."""
    from repro.models.attention import attention, chunked_attention
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    out = attention(q, q, q, causal=True, window=None, chunk=32,
                    policy=Policy())
    default = attention(q, q, q, causal=True, window=None, chunk=32)
    ref = chunked_attention(q, q, q, causal=True, window=None, chunk=32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(default), np.asarray(ref))


def test_attention_grad_under_pallas_scope(a32):
    """Training under an ambient pallas scope differentiates through
    the fused custom-VJP and agrees with the xla composition."""
    from repro.models.attention import attention
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)

    def loss(q_):
        return jnp.sum(attention(q_, q, q, causal=True, window=None,
                                 chunk=32) ** 2)

    g_x = jax.grad(loss)(q)
    with Policy(backend="pallas", interpret=True).scope():
        g_p = jax.grad(loss)(q)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_x),
                               rtol=1e-4, atol=1e-4)


def test_deprecation_shims_warn_exactly_once(a32):
    pol_mod.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        gemm.set_default_backend("xla")
        gemm.set_default_backend("xla")
        with gemm.use_backend("pallas_interpret"):
            pass
        with gemm.use_backend("xla"):
            pass
        gemm.matmul(a32, a32, backend="xla")
        gemm.matmul(a32, a32, backend="xla")
        ops.resolve_tuned("tuned")
        ops.resolve_tuned("tuned_interpret")
    msgs = [str(w.message) for w in rec
            if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 4, msgs     # one per distinct shim, not per call


def test_legacy_backend_strings_match_policies(a32):
    ref = gemm.matmul(a32, a32)
    for name in pol_mod.LEGACY_BACKEND_NAMES:
        p = Policy.from_backend(name)
        if p.backend != "xla" and not p.resolved_interpret:
            continue        # compiled-TPU path can't run on this host
        np.testing.assert_allclose(
            np.asarray(gemm.matmul(a32, a32, policy=p)), np.asarray(ref),
            rtol=2e-4)
    with pytest.raises(ValueError, match="tuned_interpret"):
        Policy.from_backend("cuda")


def test_shims_set_equivalent_policy():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        gemm.set_default_backend("tuned_interpret")
    p = current_policy()
    assert (p.backend, p.interpret, p.autotune) == ("pallas", True, "cached")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with gemm.use_backend("naive_interpret"):
            q = current_policy()
            assert (q.backend, q.interpret) == ("naive", True)
    assert current_policy() is p


# ----------------------------------------------------------------------
# registry validation
# ----------------------------------------------------------------------

def test_unknown_backend_lists_registered_options(a32):
    with pytest.raises(ValueError) as e:
        ops.matmul(a32, a32, policy=Policy(backend="cuda"))
    assert "naive" in str(e.value) and "pallas" in str(e.value) \
        and "xla" in str(e.value)


def test_unknown_epilogue_lists_registered_options(a32):
    with pytest.raises(ValueError) as e:
        ops.matmul(a32, a32, epilogue="bias_tanh")
    assert "bias_silu" in str(e.value)


def test_unknown_op_and_registry_introspection():
    with pytest.raises(ValueError, match="registered ops"):
        registry.get_impl("conv", "xla")
    assert "matmul" in registry.registered_ops()
    assert registry.registered_backends("matmul") == \
        ("naive", "pallas", "xla")


def test_unknown_autotune_mode_rejected():
    with pytest.raises(ValueError, match="autotune"):
        Policy(autotune="always")


def test_unknown_policy_field_rejected():
    with pytest.raises(ValueError, match="unknown policy field"):
        Policy.parse("backend=pallas,turbo=on")


def test_kv_fields_fingerprint_and_validation():
    # defaults must leave both fingerprints byte-identical to the
    # pre-paged era: old tuning.json keys and BENCH rows stay valid
    assert Policy().kernel_fingerprint == "xla"
    assert Policy(backend="pallas").kernel_fingerprint in \
        ("pallas", "pallas_interpret")      # interpret resolves per host
    assert "kv" not in Policy().fingerprint()
    assert "paged" not in Policy().fingerprint()
    p = Policy(kv_layout="paged", quant_kv="int8")
    assert Policy.parse(p.fingerprint()) == p
    kf = p.kernel_fingerprint
    assert kf.endswith("_kvint8_paged"), kf
    with pytest.raises(ValueError):
        Policy(kv_layout="rows")
    with pytest.raises(ValueError):
        Policy(quant_kv="fp8")
