"""End-to-end behaviour tests: training reduces loss; serving generates;
the paper's qualitative claims hold in the analytical model."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import blocking, intensity
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training import train_loop as TL


def test_training_reduces_loss():
    cfg = C.get_config("qwen3-0.6b", reduced=True)
    opt = AdamW(lr=cosine_schedule(2e-3, 5, 60))
    state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(TL.make_train_step(cfg, opt), donate_argnums=(0,))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=8)
    losses = []
    for i in range(40):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_serving_generates_finite_tokens():
    from repro.launch.serve import main as serve_main
    gen = serve_main(["--arch", "mamba2-2.7b", "--reduced", "--batch", "2",
                      "--prompt-len", "16", "--gen", "8"])
    assert gen.shape == (2, 8)
    assert (gen >= 0).all()


def test_paper_claim_tiling_wins_modeled():
    """Claim C2 on the v5e model: tiled GEMM attains >=50x the naive
    effective FLOP/s at the paper's 4096^2 size."""
    n = 4096
    tiled_cfg = blocking.choose_block_config(n, n, n, 4)
    tiled = blocking.gemm_time_model(n, n, n, 4, tiled_cfg)
    naive = blocking.gemm_time_model(n, n, n, 4, None)
    assert tiled["bound"] == "compute"
    assert naive["bound"] == "memory"
    assert naive["t_total"] / tiled["t_total"] > 50


def test_paper_claim_add_gains_nothing():
    """Claim C3: matrix add attains <1% of peak on any chip model."""
    prof = intensity.classify(intensity.add_profile(4096, 4096, 4),
                              itemsize=4)
    assert prof["bound"] == "memory"
    assert prof["attainable_flops"] < 0.01 * 65e12


def test_gemm_speedup_ordering_matches_table2():
    """Modeled per-chip GEMM times must reproduce the paper's ordering:
    C1060 > C2050-naive > C2050-shared (Table 2)."""
    from repro.core import hw
    n = 4096
    t = {}
    for chip in (hw.TESLA_C1060, hw.TESLA_C2050):
        cfgb = blocking.choose_block_config(n, n, n, 4, chip=chip)
        t[chip.name + "-shared"] = blocking.gemm_time_model(
            n, n, n, 4, cfgb, chip=chip)["t_total"]
        t[chip.name + "-naive"] = blocking.gemm_time_model(
            n, n, n, 4, None, chip=chip)["t_total"]
    assert t["tesla-c1060-naive"] > t["tesla-c2050-naive"]
    assert t["tesla-c2050-naive"] > t["tesla-c2050-shared"]
