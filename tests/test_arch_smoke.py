"""Per-architecture smoke tests (spec-required): REDUCED config of each
family, one forward/train step on CPU, asserting output shapes and no
NaNs — plus decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M


def make_batch(cfg, rng, B=2, S=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, jnp.float32)
        pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_ctx, cfg.d_model)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name, **overrides):
        key = (name, tuple(sorted(overrides.items())))
        if key not in cache:
            import dataclasses
            cfg = C.get_config(name, reduced=True)
            if cfg.moe is not None:
                # decode-vs-forward equality needs drop-free routing
                # (grouping differs between prefill and full forward)
                overrides.setdefault("moe", dataclasses.replace(
                    cfg.moe, capacity_factor=8.0))
            cfg = dataclasses.replace(cfg, **overrides)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            cache[key] = (cfg, params)
        return cache[key]
    return get


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_forward_and_train_step(arch_state, rng, name):
    cfg, params = arch_state(name)
    batch = make_batch(cfg, rng)
    logits, _ = M.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), name

    loss, metrics = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), name
    # at least one nonzero gradient leaf
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), name


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_prefill_decode_matches_forward(arch_state, rng, name):
    """Autoregressive consistency: full-sequence forward logits at
    position t must match prefill(t tokens) + decode steps. Run in f32
    activations so the tolerance is meaningful (bf16 path differences
    between the chunked-prefill and recurrent-decode forms are noise,
    not bugs — the f32 check is the real invariant)."""
    cfg, params = arch_state(name, dtype="float32")
    B, S, GEN = 2, 24, 4
    batch = make_batch(cfg, rng, B, S + GEN)
    if "patch_embeds" in batch:
        # image patches live in the prompt; generated positions are text
        batch["patch_embeds"] = batch["patch_embeds"].at[:, S:].set(0.0)
    full_logits, _ = M.forward(cfg, params, batch)

    prompt = {k: (v[:, :S] if v.ndim >= 2 and v.shape[1] == S + GEN else v)
              for k, v in batch.items() if k != "labels"}
    cache = M.init_cache(cfg, B, S + GEN)
    logits, cache = M.prefill(cfg, params, prompt, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]), np.asarray(full_logits[:, S - 1]),
        rtol=5e-3, atol=5e-3, err_msg=f"{name} prefill")

    for i in range(GEN):
        tok = batch["tokens"][:, S + i][:, None]
        logits, cache = M.decode_step(cfg, params, tok, jnp.int32(S + i),
                                      cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, S + i]),
            rtol=5e-3, atol=5e-3, err_msg=f"{name} decode step {i}")


def test_swa_decode_fast_path(rng):
    """Mixtral's sliding-window decode path (cache slice) must equal the
    full-cache masked attention."""
    import dataclasses
    cfg = C.get_config("mixtral-8x22b", reduced=True)
    # long cache so the fast path triggers (cache > 2*window); f32 +
    # drop-free routing so equality is exact (see consistency test)
    cfg = dataclasses.replace(
        cfg, window=8, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 48
    batch = make_batch(cfg, rng, B, S)
    full_logits, _ = M.forward(cfg, params, batch)
    cache = M.init_cache(cfg, B, 64)
    logits, cache = M.prefill(
        cfg, params, {"tokens": batch["tokens"][:, :S - 1]}, cache)
    tok = batch["tokens"][:, S - 1][:, None]
    logits, _ = M.decode_step(cfg, params, tok, jnp.int32(S - 1), cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=5e-3, atol=5e-3)


def test_param_counts_full_configs():
    """Full (non-reduced) configs must build shape trees matching their
    published parameter scale (sanity, via eval_shape: no allocation)."""
    from repro.roofline.analysis import count_params
    expected = {
        "qwen3-0.6b": (0.4e9, 1.2e9),
        "qwen1.5-32b": (28e9, 38e9),
        "granite-3-8b": (7e9, 10e9),
        # granite-20b is "20B" as GPT-BigCode (2-matrix GELU MLP); the
        # assignment pins llama-arch (SwiGLU, 3 matrices) at the same
        # d_ff -> 28.2B parameters. Recorded in DESIGN §6.
        "granite-20b": (18e9, 30e9),
        "mixtral-8x22b": (120e9, 150e9),
        "arctic-480b": (400e9, 520e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "whisper-tiny": (25e6, 80e6),
        "qwen2-vl-2b": (1.2e9, 2.4e9),
    }
    for name, (lo, hi) in expected.items():
        total, active = count_params(C.get_config(name))
        assert lo <= total <= hi, (name, total)
        assert active <= total
