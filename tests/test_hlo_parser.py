"""HLO analyzer parser edge cases (beyond the end-to-end checks in
test_roofline)."""

from repro.roofline import hlo as H


def test_tuple_result_and_comment_parsing():
    text = """
HloModule t

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]{1,0}, /*index=2*/f32[4,4]{1,0}) tuple(%p)
  ROOT %r = f32[8,8]{1,0} add(%p, %p)
}
"""
    comps = H.parse_module(text)
    assert "main" in comps
    ops = {i.op for i in comps["main"].instrs}
    assert "tuple" in ops and "add" in ops
    # tuple shapes parsed (3 shapes incl comment-separated)
    tup = [i for i in comps["main"].instrs if i.op == "tuple"][0]
    assert len(tup.result_shapes) == 3


def test_group_size_formats():
    assert H._group_size("replica_groups=[4,2]<=[8]", 8) == 2
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 8) == 4
    assert H._group_size("replica_groups={}", 8) == 8
    assert H._group_size("no groups here", 16) == 16


def test_collective_ici_models():
    mk = lambda op, n, p: H.Collective(op, n, p, 1, "x")
    n = 1024
    assert mk("all-reduce", n, 4).ici_bytes == 2 * n * 3 / 4
    assert mk("all-gather", n, 4).ici_bytes == n * 3 / 4
    assert mk("reduce-scatter", n, 4).ici_bytes == n * 3
    assert mk("collective-permute", n, 4).ici_bytes == n
    assert mk("all-reduce", n, 1).ici_bytes == 0.0


def test_dtype_bytes_table():
    assert H._shape_bytes([("bf16", (4, 4))]) == 32
    assert H._shape_bytes([("f32", ()), ("s8", (8,))]) == 12
    assert H._shape_bytes([("c64", (2,))]) == 16


def test_nested_while_multiplier():
    text = """
HloModule t

%inner_body (t: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %t = (s32[], f32[128,128]{1,0}) parameter(0)
  %g = f32[128,128]{1,0} get-tuple-element(%t), index=1
  %i = s32[] get-tuple-element(%t), index=0
  %d = f32[128,128]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %o = (s32[], f32[128,128]{1,0}) tuple(%i, %d)
}

%inner_cond (t: (s32[], f32[128,128])) -> pred[] {
  %t = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[128,128]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[128,128]{1,0}) while(%tup), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""
    costs = H.analyze(text, 1)
    assert costs.flops == 5 * 2 * 128 ** 3
