"""Speculative decoding: token-exactness vs the non-spec engine,
batched verification, the leftover/residual acceptance rule, positional
KV rollback, and the workload scenario registry.

The exactness oracle is the plain continuous-batching engine: same
config, same prompts, no draft. A greedy spec engine — whatever the
draft proposes, however often it is rejected — must emit exactly the
same token streams, because greedy acceptance degenerates to argmax
agreement per position. The rollback oracle is sharper: two draft
decoders whose caches differ ONLY in stale rows past the pending
position must produce bitwise-identical rounds, proving the stale rows
are dead weight (never attended, always overwritten) rather than
rolled back transactionally.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import Policy
from repro.models import model as M
from repro.serving import (ServingEngine, SpecDecoder, make_sampler,
                           make_trace, residual_distribution,
                           bursty_trace, long_context_trace,
                           synthetic_trace, TRACES)
from repro.serving.faults import FaultInjector
from repro.serving.sampler import Sampler
from repro.serving.workload import get_trace

PROMPT_LENS = [8, 24, 13, 40]     # 13 exercises the bucket remainder
GENS = [5, 4, 7, 6]


def _prompts(cfg, seed=42, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
            for l in lens]


def _run(eng, prompts, gens):
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    rep = eng.run()
    return reqs, rep


def _spy_vstep(eng):
    """Wrap the engine's jitted verify step, counting invocations."""
    calls = []
    orig = eng._vstep

    def spy(*a):
        calls.append(1)
        return orig(*a)

    eng._vstep = spy
    return calls


# -- greedy token-exactness vs the non-spec engine ----------------------

def test_spec_greedy_exact_dense_self_draft_batched_verify():
    """Self-draft (draft params = target params): every greedy proposal
    is what the target would emit, so acceptance is 1.0, and the verify
    spy shows MANY tokens per verify call — the one-batched-forward
    claim, not k decode steps in a trench coat."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg)

    ref_eng = ServingEngine(cfg, params, max_slots=2, max_len=64)
    ref_reqs, _ = _run(ref_eng, prompts, GENS)

    eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                        draft=(cfg, params), spec_k=4)
    calls = _spy_vstep(eng)
    reqs, rep = _run(eng, prompts, GENS)

    for r, ref in zip(reqs, ref_reqs):
        assert r.generated == ref.generated
    assert rep["n_finished"] == len(reqs)
    assert rep["spec_rounds"] == len(calls) > 0
    assert rep["spec_acceptance_rate"] == 1.0
    # decode tokens (everything past the prefill token) per verify call:
    # batched verification must beat one-token-per-step decode
    decode_tokens = sum(len(r.generated) - 1 for r in reqs)
    assert decode_tokens > len(calls)
    assert rep["tokens_per_step"] > 1.5
    for r in reqs:
        assert r.acceptance_rate == 1.0 and r.draft_proposed > 0


def test_spec_greedy_exact_dense_mismatched_draft():
    """An unrelated random-weights draft is wrong about everything
    (~1/vocab acceptance) — the stream must STILL be token-exact; the
    rejection path re-emits the target argmax at every position."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dcfg = get_config("granite-3-8b", reduced=True)
    dparams = M.init_params(dcfg, jax.random.PRNGKey(7))
    prompts = _prompts(cfg)

    ref_eng = ServingEngine(cfg, params, max_slots=2, max_len=64)
    ref_reqs, _ = _run(ref_eng, prompts, GENS)

    eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                        draft=(dcfg, dparams), spec_k=3)
    reqs, rep = _run(eng, prompts, GENS)
    for r, ref in zip(reqs, ref_reqs):
        assert r.generated == ref.generated
    assert rep["spec_acceptance_rate"] < 0.5


def test_spec_greedy_exact_paged_int8():
    """Spec decoding over the paged int8-KV target: the verify step
    scatters k+1 quantized rows per slot and attends through the page
    table. Exactness oracle is the non-spec engine under the SAME
    policy (int8 KV rounds logits identically in both)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(kv_layout="paged", quant_kv="int8")
    prompts = _prompts(cfg)

    ref_eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                            policy=pol, page_size=8)
    ref_reqs, _ = _run(ref_eng, prompts, GENS)

    eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                        policy=pol, page_size=8,
                        draft=(cfg, params), spec_k=4)
    calls = _spy_vstep(eng)
    reqs, rep = _run(eng, prompts, GENS)
    for r, ref in zip(reqs, ref_reqs):
        assert r.generated == ref.generated
    assert rep["spec_rounds"] == len(calls) > 0
    # draft is dense f32 while the target sees int8-rounded KV, so the
    # two disagree on a few positions — acceptance is high, not 1.0
    assert rep["spec_acceptance_rate"] > 0.5
    assert rep["tokens_per_step"] > 1.5


# -- acceptance rule ----------------------------------------------------

def test_residual_distribution():
    p = np.array([0.5, 0.3, 0.2, 0.0])
    q = np.array([0.1, 0.6, 0.1, 0.2])
    r = residual_distribution(p, q)
    want = np.array([0.4, 0.0, 0.1, 0.0]) / 0.5
    np.testing.assert_allclose(r, want)
    # q covers p pointwise -> no residual mass -> falls back to p
    np.testing.assert_allclose(residual_distribution(p, p), p)


def test_speculative_accept_matches_residual_rule():
    """Mirror the sampler's rng stream and hand-roll the leftover rule:
    accept x_j iff u * q_j(x_j) <= p_j(x_j); first rejection draws from
    norm(max(p_j - q_j, 0)) and stops; full acceptance draws the bonus
    from the last target row."""
    rng = np.random.default_rng(3)
    vocab, k = 8, 4
    sampler = make_sampler("temperature", temperature=1.0, seed=11)
    mirror = np.random.default_rng(11)
    for _ in range(50):
        tl = rng.normal(size=(k + 1, vocab)).astype(np.float32)
        qp = rng.dirichlet(np.ones(vocab), size=k)
        dt = [int(rng.integers(vocab)) for _ in range(k)]

        ps = [sampler.probs(tl[j]) for j in range(k + 1)]
        want, want_acc = [], k
        for j in range(k):
            x, q = dt[j], qp[j]
            if q[x] > 0 and mirror.random() * q[x] <= ps[j][x]:
                want.append(x)
                continue
            res = residual_distribution(ps[j], q)
            want.append(int(mirror.choice(vocab, p=res)))
            want_acc = j
            break
        else:
            want.append(int(mirror.choice(vocab, p=ps[k])))

        got, n_acc = sampler.speculative_accept(tl, dt, qp)
        assert got == want and n_acc == want_acc


def test_speculative_accept_stream_is_distribution_identical():
    """The point of the rule: the emitted first token's distribution
    equals the target distribution, for ANY draft q. Empirical check on
    a small vocab with a deliberately bad draft."""
    vocab, trials = 4, 20000
    rng = np.random.default_rng(0)
    tl = np.array([[1.0, 0.2, -0.5, 0.1]], np.float32)  # k=0 won't do;
    tl = np.vstack([tl, np.zeros((1, vocab), np.float32)])  # k=1 + bonus
    q = np.array([[0.7, 0.1, 0.1, 0.1]])                # skewed draft
    sampler = make_sampler("temperature", temperature=1.0, seed=5)
    p = sampler.probs(tl[0])
    counts = np.zeros(vocab)
    for _ in range(trials):
        x = int(rng.choice(vocab, p=q[0]))              # draft proposes
        emitted, _ = sampler.speculative_accept(tl, [x], q)
        counts[emitted[0]] += 1
    np.testing.assert_allclose(counts / trials, p, atol=0.015)


def test_speculative_accept_greedy_is_argmax_exact():
    sampler = Sampler()
    tl = np.array([[0.0, 2.0, 1.0],     # argmax 1
                   [3.0, 0.0, 1.0],     # argmax 0
                   [0.0, 0.0, 9.0]],    # bonus row, argmax 2
                  np.float32)
    # both drafts right -> all accepted + bonus
    assert sampler.speculative_accept(tl, [1, 0]) == ([1, 0, 2], 2)
    # second draft wrong -> corrected in place, stream stops there
    assert sampler.speculative_accept(tl, [1, 2]) == ([1, 0], 1)
    # first draft wrong -> single corrected token
    assert sampler.speculative_accept(tl, [0, 0]) == ([1], 0)


# -- positional rollback ------------------------------------------------

def test_draft_rollback_is_positional_bitwise():
    """Two draft decoders with identical valid state but DIFFERENT
    stale rows past the pending position must produce bitwise-identical
    next rounds: decoder A ran a full k-draft round (stale rows
    pos+1..pos+k), decoder B a 1-draft round (stale row pos+1 only).
    After the same rejection-correction feed, drafts and the
    newly-written cache rows must agree exactly — stale rows are never
    attended and always overwritten, no transactional rollback."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    L = 12
    ctx = rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
    k = 4

    def decoder():
        d = SpecDecoder(cfg, params, max_slots=1, max_len=48, spec_k=k)
        d.admit(0, ctx)
        return d

    pos = np.array([L], np.int32)
    tok = np.array([[5]], np.int32)

    da, db = decoder(), decoder()
    ra, _ = da.draft_round(tok, pos, np.array([k], np.int32))
    rb, _ = db.draft_round(tok, pos, np.array([1], np.int32))
    assert ra[0, 0] == rb[0, 0]         # same first draft either way

    # simulate rejecting draft 0: correction token c becomes pending at
    # pos+1 — overwrite the stale row and draft again from both caches
    c = np.array([[int(ra[0, 0]) ^ 1]], np.int32)   # any token != d0
    pos1 = np.array([L + 1], np.int32)
    kv = np.array([k], np.int32)
    r2a, _ = da.draft_round(c, pos1, kv)
    r2b, _ = db.draft_round(c, pos1, kv)
    np.testing.assert_array_equal(r2a, r2b)

    # the rows both rounds wrote (pos+1 .. pos+1+k) match bitwise even
    # though A's cache held k stale rows there and B's held one
    for name in ("k", "v"):
        xa = np.asarray(da.cache[name])[:, 0, : L + 2 + k]
        xb = np.asarray(db.cache[name])[:, 0, : L + 2 + k]
        np.testing.assert_array_equal(xa, xb)


def test_spec_target_cache_matches_nonspec_rows():
    """After a run full of rejections (mismatched draft), the spec
    engine's target cache valid rows [0, L+gen-1) must match the
    non-spec engine's — every stale verify write was overwritten by the
    corrected stream. Float tolerance, not bitwise: verify attends
    multi-token (chunked) where decode attends one-token (flash)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dcfg = get_config("granite-3-8b", reduced=True)
    dparams = M.init_params(dcfg, jax.random.PRNGKey(9))
    rng = np.random.default_rng(2)
    L, gen = 10, 6
    prompt = rng.integers(0, cfg.vocab, (L,)).astype(np.int32)

    ref = ServingEngine(cfg, params, max_slots=1, max_len=32)
    (ref_req,), _ = _run(ref, [prompt], [gen])
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32,
                        draft=(dcfg, dparams), spec_k=3)
    (req,), rep = _run(eng, [prompt], [gen])

    assert req.generated == ref_req.generated
    assert rep["spec_acceptance_rate"] < 0.5    # rejections did happen
    n_valid = L + gen - 1       # the last emitted token is never fed
    for name in ("k", "v"):
        got = np.asarray(eng.cache[name])[:, 0, :n_valid]
        want = np.asarray(ref.cache[name])[:, 0, :n_valid]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# -- construction / validation ------------------------------------------

def test_spec_validation_errors():
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # chaos injection and speculation are mutually exclusive
    with pytest.raises(ValueError, match="injector"):
        ServingEngine(cfg, params, max_slots=1, max_len=32,
                      draft=(cfg, params),
                      fault_injector=FaultInjector(kernel_fail_steps=(1,)))
    # non-attention target family has no verify_step
    scfg = get_config("mamba2-2.7b", reduced=True)
    sparams = M.init_params(scfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServingEngine(scfg, sparams, max_slots=1, max_len=32,
                      draft=(cfg, params))
    # the draft cache is dense by design
    with pytest.raises(ValueError, match="dense"):
        SpecDecoder(cfg, params, max_slots=1, max_len=32,
                    policy=Policy(kv_layout="paged"))
    with pytest.raises(ValueError, match="spec_k"):
        SpecDecoder(cfg, params, max_slots=1, max_len=32, spec_k=0)


# -- workload scenario registry -----------------------------------------

def test_traces_registry_dispatch():
    assert set(TRACES) == {"mixed", "prefix_heavy", "bursty",
                           "long_context"}
    cfg = get_config("qwen3-0.6b", reduced=True)
    a = make_trace("mixed", cfg, 5, rng=np.random.default_rng(3), gen=4)
    b = synthetic_trace(cfg, 5, rng=np.random.default_rng(3), gen=4)
    assert len(a) == len(b) == 5
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.arrival == y.arrival and x.gen == y.gen
    with pytest.raises(ValueError, match="unknown"):
        get_trace("nope")


def test_bursty_trace_groups_and_preserves_rate():
    cfg = get_config("qwen3-0.6b", reduced=True)
    n, rate = 600, 8.0
    tr = bursty_trace(cfg, n, rng=np.random.default_rng(0), gen=4,
                      arrival_rate=rate, burst_mean=4.0, deadline=9.0)
    arr = np.array([t.arrival for t in tr])
    assert (np.diff(arr) >= 0).all()
    # grouped: far fewer distinct arrival instants than requests
    assert len(np.unique(arr)) < n / 2
    # compound thinning is rate-preserving: n arrivals over ~n/rate s
    assert arr[-1] == pytest.approx(n / rate, rel=0.35)
    # deadline is relative to arrival; the item stores the absolute time
    assert all(t.deadline == pytest.approx(t.arrival + 9.0) for t in tr)


def test_long_context_trace_shape():
    cfg = get_config("qwen3-0.6b", reduced=True)
    tr = long_context_trace(cfg, 8, rng=np.random.default_rng(0))
    for t in tr:
        assert 96 <= len(t.prompt) <= 160 and t.gen == 4
