"""Autotuner subsystem: cache round-trip, fingerprint safety, tuned
backend numerics, and sweep mechanics (all interpret-mode on CPU)."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocking import BlockConfig, FlashBlockConfig
from repro.kernels import ops
from repro.kernels.ref import attention_ref, matmul_ref
from repro.tuning import cache as tcache
from repro.tuning import autotuner, space


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the process-global cache at a throwaway file."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, path)
    tcache.reset_cache()
    yield path
    tcache.reset_cache()


def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "c.json")
    c = tcache.TuningCache(path, fingerprint="fp-a")
    c.put_matmul(512, 512, 512, "float32", "pallas",
                 BlockConfig(256, 128, 512), time_us=10.0, speedup=1.5)
    c.put_flash(1024, 1024, 64, "bfloat16", "pallas",
                FlashBlockConfig(128, 256), time_us=20.0)
    c.save()

    c2 = tcache.TuningCache(path, fingerprint="fp-a").load()
    assert c2.get_matmul(512, 512, 512, "float32", "pallas") == \
        BlockConfig(256, 128, 512)
    assert c2.get_flash(1024, 1024, 64, "bfloat16", "pallas") == \
        FlashBlockConfig(128, 256)
    entry = c2.entries[tcache.matmul_key(512, 512, 512, "float32", "pallas")]
    assert entry["speedup"] == 1.5 and "tuned_at" in entry


def test_save_merges_other_fingerprints(tmp_path):
    path = str(tmp_path / "c.json")
    tcache.TuningCache(path, fingerprint="fp-a").load().save()
    a = tcache.TuningCache(path, fingerprint="fp-a")
    a.put_matmul(64, 64, 64, "float32", "pallas", BlockConfig(64, 64, 64))
    a.save()
    b = tcache.TuningCache(path, fingerprint="fp-b")
    b.put_matmul(64, 64, 64, "float32", "pallas", BlockConfig(128, 128, 128))
    b.save()
    doc = json.load(open(path))
    assert set(doc["caches"]) == {"fp-a", "fp-b"}
    assert tcache.TuningCache(path, "fp-a").load().get_matmul(
        64, 64, 64, "float32", "pallas") == BlockConfig(64, 64, 64)


def test_fingerprint_mismatch_returns_none(tmp_path):
    path = str(tmp_path / "c.json")
    a = tcache.TuningCache(path, fingerprint="fp-a")
    a.put_matmul(64, 64, 64, "float32", "pallas_interpret",
                 BlockConfig(64, 64, 64))
    a.save()
    b = tcache.TuningCache(path, fingerprint="fp-b").load()
    assert b.get_matmul(64, 64, 64, "float32", "pallas_interpret") is None
    assert b.misses == 1 and b.hits == 0


def test_fingerprint_mismatch_falls_back_to_default(tmp_cache, rng):
    # A cache written on "other" hardware must be ignored: the tuned
    # backend silently uses the static chooser and stays correct.
    other = tcache.TuningCache(tmp_cache, fingerprint="some-other-machine")
    other.put_matmul(96, 96, 96, "float32", "pallas_interpret",
                     BlockConfig(8, 128, 128))
    other.save()
    tcache.reset_cache()
    a = jnp.asarray(rng.normal(size=(96, 96)), jnp.float32)
    out = ops.matmul(a, a, backend="tuned_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, a)),
                               rtol=1e-4, atol=1e-3)
    assert tcache.get_cache().get_matmul(
        96, 96, 96, "float32", "pallas_interpret") is None


def test_tuned_matches_tiled_numerics(tmp_cache, rng):
    a = jnp.asarray(rng.normal(size=(96, 160)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(160, 112)), jnp.float32)
    tuned = ops.matmul(a, b, backend="tuned_interpret")
    tiled = ops.matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(tiled),
                               rtol=1e-5, atol=1e-5)


def test_tuned_serves_cached_config(tmp_cache, rng):
    # A non-default (but valid) config planted in the cache must be
    # served — observable via the hit counter — and stay correct.
    c = tcache.get_cache()
    c.put_matmul(128, 128, 128, "float32", "pallas_interpret",
                 BlockConfig(64, 128, 128))
    c.save()
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    hits0 = c.hits
    out = ops.matmul(a, a, backend="tuned_interpret")
    assert c.hits == hits0 + 1
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, a)),
                               rtol=1e-4, atol=1e-3)


def test_tuned_flash_matches_ref(tmp_cache, rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    out = ops.flash_attention(q, q, q, causal=True, backend="tuned_interpret")
    ref = attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_matmul_candidates_feasible():
    cands = space.matmul_candidates(4096, 4096, 4096, itemsize=2)
    assert len(cands) > 1
    from repro.core import hw
    budget = hw.DEFAULT_CHIP.vmem_bytes * 0.5
    assert all(c.vmem_bytes(2) <= budget for c in cands)
    assert len({(c.bm, c.bn, c.bk) for c in cands}) == len(cands)
    # the static chooser's pick leads the sweep (it is the baseline)
    from repro.core import blocking
    assert cands[0] == blocking.choose_block_config(4096, 4096, 4096, 2)


def test_flash_candidates_divide_sequences():
    cands = space.flash_candidates(1024, 2048, 128, itemsize=2)
    assert all(1024 % c.bq == 0 and 2048 % c.bk == 0 for c in cands)


def test_tune_matmul_populates_cache(tmp_cache):
    res = autotuner.tune_matmul(128, 128, 128, "float32",
                                backend="pallas_interpret",
                                warmup=0, iters=1, max_candidates=3)
    assert res.best_s > 0 and len(res.trials) >= 1
    served = tcache.TuningCache(tmp_cache).load().get_matmul(
        128, 128, 128, "float32", "pallas_interpret")
    assert served == res.best


def test_warm_start_reports_then_hits(tmp_cache):
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b", reduced=True)
    rep = autotuner.warm_start(cfg, batch=2, seq=16, autotune=False)
    assert rep["tuned"] == [] and rep["hits"] == []
    expected = (len(autotuner.model_gemm_shapes(cfg, 2, 16))
                + len(autotuner.model_attention_shapes(cfg, 2, 16)))
    assert len(rep["misses"]) == expected
    rep2 = autotuner.warm_start(cfg, batch=2, seq=16, autotune=True,
                                iters=1, max_candidates=2)
    assert len(rep2["tuned"]) == len(rep["misses"])
    rep3 = autotuner.warm_start(cfg, batch=2, seq=16, autotune=False)
    assert len(rep3["hits"]) == len(rep["misses"]) and rep3["misses"] == []


def test_warm_start_covers_attention_shapes(tmp_cache):
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b", reduced=True)
    rep = autotuner.warm_start(cfg, batch=2, seq=16, autotune=False,
                               backward=True, decode_len=64)
    ops_seen = {e[0] for e in rep["misses"]}
    assert {"flash", "flash_bwd", "flash_decode"} <= ops_seen


def test_model_attention_shapes_skips_ssm():
    from repro.configs import get_config
    cfg = get_config("mamba2-2.7b", reduced=True)
    assert autotuner.model_attention_shapes(cfg, 2, 16) == []


def test_flash_decode_candidates_divide_cache():
    cands = space.flash_decode_candidates(2048, 64, itemsize=2)
    assert all(c.bq == 1 and 2048 % c.bk == 0 for c in cands)
    assert len({c.bk for c in cands}) == len(cands)
    from repro.core import blocking
    assert cands[0] == blocking.choose_decode_config(2048, 64, 2)


def test_flash_bwd_candidates_feasible():
    cands = space.flash_bwd_candidates(1024, 2048, 128, itemsize=2)
    assert cands and all(1024 % c.bq == 0 and 2048 % c.bk == 0
                         for c in cands)


def test_tune_flash_decode_populates_cache(tmp_cache):
    pol_fp = "pallas_interpret"
    res = autotuner.tune_flash_decode(256, 32, "float32", backend=pol_fp,
                                      batch=2, warmup=0, iters=1,
                                      max_candidates=2)
    assert res.best_s > 0 and res.best.bq == 1
    served = tcache.TuningCache(tmp_cache).load().get_flash_decode(
        256, 32, "float32", pol_fp)
    assert served == res.best


def test_tune_flash_bwd_populates_cache(tmp_cache):
    pol_fp = "pallas_interpret"
    res = autotuner.tune_flash_bwd(256, 256, 32, "float32", backend=pol_fp,
                                   warmup=0, iters=1, max_candidates=2)
    assert res.best_s > 0
    served = tcache.TuningCache(tmp_cache).load().get_flash_bwd(
        256, 256, 32, "float32", pol_fp)
    assert served == res.best


def test_flash_decode_paged_candidates_divide_page():
    cands = space.flash_decode_paged_candidates(16, 64, itemsize=4)
    assert cands and all(c.bq == 1 and 16 % c.bk == 0 and c.bk <= 16
                         for c in cands)
    assert cands[0].bk == 16            # whole-page default first
    assert len({c.bk for c in cands}) == len(cands)


def test_tune_flash_decode_paged_populates_cache(tmp_cache):
    pol_fp = "pallas_interpret"
    res = autotuner.tune_flash_decode_paged(16, 32, "float32",
                                            backend=pol_fp, batch=2,
                                            pages_per_slot=2, warmup=0,
                                            iters=1, max_candidates=2)
    assert res.best_s > 0 and res.best.bq == 1
    served = tcache.TuningCache(tmp_cache).load().get_flash_decode_paged(
        16, 32, "float32", pol_fp)
    assert served == res.best
