"""Fault-tolerant serving under deterministic chaos.

Every fault here is *scripted* — the FaultInjector fires at fixed
decode-step counters and admission ordinals, never off a clock or an
RNG — so each recovery path is pinned by an exact-output assertion:

  * a NaN'd logits row quarantines exactly the poisoned slot while the
    co-scheduled streams stay token-exact vs the fault-free reference;
  * a preempted victim (pages reclaimed, re-prefilled on resume) ends
    byte-identical to an uninterrupted run;
  * repeated kernel faults degrade the engine to the xla registry
    backend (warning once) and the trace still completes exactly;
  * the report's fault counters and goodput stay sum-consistent.

The reference oracle is _reference_generate from test_serving: one
whole-prompt prefill + scalar-pos greedy decode, batch 1.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import Policy
from repro.models import model as M
from repro.serving import FaultInjector, ServingEngine, SimulatedKernelFault
from repro.serving.request import (ACTIVE, CANCELLED, EXPIRED, FINISHED,
                                   QUARANTINED, WAITING)
from test_serving import _reference_generate


def _setup(arch="qwen3-0.6b", seed=0):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _prompts(cfg, lengths, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
            for l in lengths]


def _check_consistency(engine, report):
    """Acceptance (c): counters and goodput must sum consistently."""
    reqs = engine.requests
    n = len(reqs)
    by = {s: sum(1 for r in reqs if r.status == s)
          for s in (FINISHED, EXPIRED, CANCELLED, QUARANTINED)}
    assert report["n_finished"] == by[FINISHED]
    assert report["expired"] == by[EXPIRED]
    assert report["cancelled"] == by[CANCELLED]
    assert report["quarantined"] == by[QUARANTINED]
    assert sum(by.values()) == n, (by, n)
    assert engine.tokens_emitted == sum(r.n_generated for r in reqs)
    useful = sum(r.n_generated for r in reqs
                 if r.status == FINISHED and r.missed_deadline is not True)
    assert report["useful_tokens"] == useful
    assert report["goodput"] == useful / max(engine.tokens_emitted, 1)
    assert 0.0 <= report["goodput"] <= 1.0


# ---------------------------------------------------------------- injector

def test_fault_injector_scripting_and_fire_once():
    inj = FaultInjector(nan_rows={3: 1}, corrupt_pages={2: (0, 1)},
                        kernel_fail_steps=(5,), slow_steps={4: 0.0},
                        deny_admissions=(1,))
    # slot-map normalization: scalar -> tuple
    assert inj.nan_rows == {3: (1,)}
    assert inj.corrupt_pages == {2: (0, 1)}
    # wrong step / inactive slot: no-op, nothing fired
    rows = np.zeros((2, 4), np.float32)
    assert inj.poison_rows(0, rows, (0, 1)) is rows
    assert inj.poison_rows(3, rows, (0,)) is rows       # slot 1 not active
    # scripted step: returns a poisoned COPY, original untouched
    out = inj.poison_rows(3, rows, (0, 1))
    assert out is not rows and np.isfinite(rows).all()
    assert np.isnan(out[1]).all() and np.isfinite(out[0]).all()
    # fire-once: a second pass at the same step is clean
    assert inj.poison_rows(3, rows, (0, 1)) is rows
    assert inj.corrupt_slots(2, (0, 1, 2)) == (0, 1)
    assert inj.corrupt_slots(2, (0, 1, 2)) == ()
    with pytest.raises(SimulatedKernelFault):
        inj.before_kernel(5)
    inj.before_kernel(5)                                # retry sails through
    inj.before_kernel(4)                                # slow step (0s sleep)
    assert inj.deny_admission(1) and not inj.deny_admission(1)
    assert not inj.deny_admission(0)
    assert inj.report() == {"nan_rows": 1, "page_corruptions": 2,
                            "kernel_faults": 1, "slow_steps": 1,
                            "denied_admissions": 1}


# ------------------------------------------------------- NaN quarantine (a)

def test_nan_quarantines_exact_slot_others_token_exact():
    """Acceptance (a): the poisoned slot is quarantined at the scripted
    step with a diagnostic; every other stream — including the request
    admitted into the freed slot — matches the fault-free reference."""
    cfg, params = _setup()
    lens, gens = [12, 16, 10], [6, 6, 5]
    prompts = _prompts(cfg, lens)
    inj = FaultInjector(nan_rows={2: 0})        # slot 0 = request 0
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        fault_injector=inj)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    report = eng.run()

    bad = reqs[0]
    assert bad.status == QUARANTINED
    assert bad.error == "non-finite logits at decode step 2"
    # prefill + decode steps 0,1 emitted 3 tokens; poisoned step 2 did not
    assert len(bad.generated) == 3
    assert report["quarantined"] == 1 and report["n_finished"] == 2
    for req, prompt, g in zip(reqs[1:], prompts[1:], gens[1:]):
        assert req.status == FINISHED
        assert req.generated == _reference_generate(cfg, params, prompt, g)
    assert report["faults_injected"]["nan_rows"] == 1
    assert report["goodput"] < 1.0              # the 2 poisoned-slot tokens
    _check_consistency(eng, report)


def test_page_corruption_quarantines_through_attention_math():
    """A NaN'd PRIVATE page surfaces through real attention math and
    quarantines only the owning slot; the co-resident stream (whose
    pages are untouched by construction) stays token-exact."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [10, 14], seed=23)
    inj = FaultInjector(corrupt_pages={2: 1})   # slot 1, mid-page write pos
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8,
                        fault_injector=inj)
    r0, r1 = [eng.submit(p, 6) for p in prompts]
    report = eng.run()
    assert r1.status == QUARANTINED and r1.error
    assert r0.status == FINISHED
    assert r0.generated == _reference_generate(cfg, params, prompts[0], 6)
    assert report["faults_injected"]["page_corruptions"] == 1
    # quarantine released the slot's pages: the pool fully drains
    assert (eng.pool.refcount == 0).all()
    _check_consistency(eng, report)


# -------------------------------------------------- preempt + resume (b)

def test_preempt_resume_byte_identical():
    """Acceptance (b): forced pool exhaustion at a scripted admission
    preempts the lower-priority victim mid-decode (pages reclaimed);
    the victim re-prefills prompt+generated on resume and finishes
    BYTE-IDENTICAL to an uninterrupted run."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [12, 10], seed=31)
    inj = FaultInjector(deny_admissions=(1,))   # second admission sees
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,  # no pages
                        policy=Policy(kv_layout="paged"), page_size=8,
                        fault_injector=inj, preempt_backoff=0.005)
    victim = eng.submit(prompts[0], 8, priority=0)
    for _ in range(3):              # prefill token + 3 decode tokens
        eng.step()
    assert victim.status == ACTIVE and len(victim.generated) == 4
    vip = eng.submit(prompts[1], 4, priority=1)
    report = eng.run()

    assert report["preempted"] == 1 and victim.preemptions == 1
    assert report["faults_injected"]["denied_admissions"] == 1
    assert vip.status == FINISHED and victim.status == FINISHED
    assert vip.generated == _reference_generate(cfg, params, prompts[1], 4)
    assert victim.generated == _reference_generate(cfg, params, prompts[0], 8)
    assert (eng.pool.refcount == 0).all()
    _check_consistency(eng, report)


def test_equal_priority_exhaustion_defers_not_preempts():
    """A denied admission with no strictly-lower-priority victim must
    defer FCFS (no churn), exactly like organic pool exhaustion."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [12, 10], seed=37)
    inj = FaultInjector(deny_admissions=(1,))
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8,
                        fault_injector=inj)
    r0 = eng.submit(prompts[0], 6)
    eng.step()
    r1 = eng.submit(prompts[1], 4)              # same priority: no victim
    report = eng.run()
    assert report["preempted"] == 0 and r0.preemptions == 0
    assert r0.status == FINISHED and r1.status == FINISHED
    assert r0.generated == _reference_generate(cfg, params, prompts[0], 6)
    assert r1.generated == _reference_generate(cfg, params, prompts[1], 4)
    _check_consistency(eng, report)


# ------------------------------------------------ kernel faults -> degrade

def test_kernel_faults_degrade_to_xla_and_trace_completes():
    import repro.serving.engine as E
    cfg, params = _setup()
    prompts = _prompts(cfg, [10, 13], seed=41)
    inj = FaultInjector(kernel_fail_steps=(1, 3))
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(backend="pallas", interpret=True),
                        fault_injector=inj, kernel_fault_threshold=2)
    reqs = [eng.submit(p, 5) for p in prompts]
    E._DEGRADE_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        report = eng.run()
    degrade_warns = [x for x in w if "degraded to the 'xla'" in str(x.message)]
    assert len(degrade_warns) == 1              # once per process
    assert report["degraded"] and eng.policy.backend == "xla"
    assert report["kernel_faults"] == 2 and report["crashed_steps"] == 0
    assert report["n_finished"] == 2
    for req, prompt in zip(reqs, prompts):
        assert req.generated == _reference_generate(cfg, params, prompt, 5)
    _check_consistency(eng, report)


def test_kernel_fault_retry_without_degrade():
    """A single transient fault is retried in place: no degrade, no
    crash, token streams exact."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [10], seed=43)
    inj = FaultInjector(kernel_fail_steps=(2,))
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32,
                        fault_injector=inj)
    req = eng.submit(prompts[0], 6)
    report = eng.run()
    assert report["kernel_faults"] == 1 and not report["degraded"]
    assert report["crashed_steps"] == 0
    assert req.generated == _reference_generate(cfg, params, prompts[0], 6)


def test_kernel_fault_retry_exhaustion_counts_crashed_step():
    cfg, params = _setup()
    prompts = _prompts(cfg, [10], seed=47)
    inj = FaultInjector(kernel_fail_steps=(0, 1))
    # fire-once is per *scripted step*; with retries disabled both
    # scripted steps raise through and the run crashes loudly
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32,
                        fault_injector=inj, max_step_retries=0)
    eng.submit(prompts[0], 4)
    with pytest.raises(SimulatedKernelFault):
        eng.run()
    assert eng.crashed_steps == 1 and eng.kernel_faults == 1


# ------------------------------------------------- deadlines + cancellation

def test_deadline_expires_waiting_request():
    """A waiter whose deadline passes before a slot frees is dropped
    without ever being admitted; actives are never killed by deadline."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [10, 10], seed=53)
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32)
    r0 = eng.submit(prompts[0], 8)
    r1 = eng.submit(prompts[1], 4, deadline=1e-4)   # expires in the queue
    report = eng.run()
    assert r0.status == FINISHED
    assert r1.status == EXPIRED and r1.t_admitted is None
    assert r1.missed_deadline is True and r1.n_generated == 0
    assert report["expired"] == 1
    assert report["deadline_miss_rate"] == 1.0      # only r1 had a deadline
    assert report["goodput"] == 1.0                 # r1 wasted no decode
    _check_consistency(eng, report)


def test_deadline_validation_and_finished_miss_accounting():
    cfg, params = _setup()
    prompts = _prompts(cfg, [8], seed=59)
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32)
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(prompts[0], 4, arrival_time=1.0, deadline=0.5)
    # a FINISHED request that beat a generous deadline is not a miss
    req = eng.submit(prompts[0], 4, deadline=60.0)
    report = eng.run()
    assert req.status == FINISHED and req.missed_deadline is False
    assert report["deadline_miss_rate"] == 0.0 and report["goodput"] == 1.0


def test_cancel_waiting_active_and_terminal():
    cfg, params = _setup()
    prompts = _prompts(cfg, [10, 12, 10], seed=61)
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8)
    r0 = eng.submit(prompts[0], 6)
    r1 = eng.submit(prompts[1], 6)
    eng.step()                                  # r0 active, r1 waiting
    assert r0.status == ACTIVE and r1.status == WAITING
    assert eng.cancel(r1.rid)                   # cancel a waiter
    assert r1.status == CANCELLED and r1.slot == -1
    assert eng.cancel(r0.rid)                   # cancel the active request
    assert r0.status == CANCELLED
    assert (eng.pool.refcount == 0).all()       # pages reclaimed NOW
    assert eng.pool.n_reserved == 0
    assert not eng.cancel(r0.rid)               # terminal: no-op, False
    with pytest.raises(ValueError, match="unknown request"):
        eng.cancel(999)
    r2 = eng.submit(prompts[2], 4)              # engine still serves
    report = eng.run()
    assert r2.status == FINISHED
    assert r2.generated == _reference_generate(cfg, params, prompts[2], 4)
    assert report["cancelled"] == 2
    _check_consistency(eng, report)


def test_cancel_cow_sharer_keeps_survivor_exact():
    """Cancel one of two prefix-sharing requests right after its CoW
    split: refcounts on the shared pages drop but the survivor keeps
    decoding on intact pages, token-exact to the end."""
    cfg, params = _setup()
    rng = np.random.default_rng(67)
    prompt = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8)
    r0 = eng.submit(prompt.copy(), 6)
    r1 = eng.submit(prompt.copy(), 6)
    eng.step()                                  # both admitted; tail CoW'd
    assert eng.pool.stats.cow_copies == 1
    assert eng.cancel(r0.rid)
    report = eng.run()
    assert r1.status == FINISHED
    assert r1.generated == _reference_generate(cfg, params, prompt, 6)
    assert (eng.pool.refcount == 0).all()
    _check_consistency(eng, report)


# ------------------------------------------------------------- stragglers

def test_slow_step_flags_straggler():
    cfg, params = _setup()
    prompts = _prompts(cfg, [8], seed=71)
    inj = FaultInjector(slow_steps={5: 0.25})
    eng = ServingEngine(cfg, params, max_slots=1, max_len=32,
                        fault_injector=inj)
    eng.submit(prompts[0], 8)
    report = eng.run()
    assert report["faults_injected"]["slow_steps"] == 1
    assert report["straggler_steps"] >= 1
    assert any(step == 5 for step, _, _ in eng.straggler.flagged)


# ------------------------------------------------------- combined chaos

def test_combined_chaos_counts_stay_consistent():
    """NaN + denial + kernel fault + cancel in one run: the engine keeps
    serving and every counter in the report stays sum-consistent."""
    cfg, params = _setup()
    lens = [12, 10, 14, 10, 8]
    prompts = _prompts(cfg, lens, seed=73)
    inj = FaultInjector(nan_rows={4: 1}, kernel_fail_steps=(6,),
                        deny_admissions=(2,))
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8,
                        fault_injector=inj, preempt_backoff=0.005)
    reqs = [eng.submit(p, 5, priority=i % 2, deadline=60.0)
            for i, p in enumerate(prompts)]
    eng.step()
    eng.cancel(reqs[2].rid)                     # cancel a waiter mid-run
    report = eng.run()
    assert report["cancelled"] == 1 and report["quarantined"] == 1
    assert report["kernel_faults"] == 1 and report["crashed_steps"] == 0
    assert report["n_finished"] == 3
    assert (eng.pool.refcount == 0).all() and eng.pool.n_reserved == 0
    _check_consistency(eng, report)
