"""Substrate tests: optimizer, data determinism, checkpoint round-trip +
atomicity, fault-tolerant supervisor, gradient compression, SSD blocks."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import MemmapCorpus, SyntheticLM, write_corpus
from repro.distributed import compression
from repro.distributed.fault_tolerance import (
    FailureInjector, SimulatedFailure, StragglerDetector, Supervisor,
    elastic_mesh_shape)
from repro.models import ssm as S
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.training import train_loop as TL


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.ones((4, 4)) * 5.0}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw ||w||^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 2e-4
    assert float(lr(jnp.int32(5))) < 1e-3


def test_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((8,))}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.ones((8,)) * 1e6}, state, params)
    assert float(gnorm) > 1e5          # reported norm is pre-clip


def test_data_determinism_and_shards():
    d = SyntheticLM(vocab=1000, seq_len=16, batch=4, seed=7)
    b1 = d.batch_at(3, shard=0, n_shards=2)
    b2 = d.batch_at(3, shard=0, n_shards=2)
    b3 = d.batch_at(3, shard=1, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert (b1["labels"][:, -1] == -1).all()


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, np.arange(10_000) % 500)
    d = MemmapCorpus(path=path, vocab=500, seq_len=16, batch=4)
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].max() < 500
    np.testing.assert_array_equal(d.batch_at(1)["tokens"],
                                  d.batch_at(1)["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    for step in (10, 20, 30):
        ck.save(step, jax.tree.map(lambda x: x * step, tree))
    assert ck.steps() == [20, 30]          # keep=2 rotated
    out = ck.restore(30, tree)
    np.testing.assert_allclose(np.asarray(out["a"], np.float32),
                               np.asarray(tree["a"]) * 30)


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((64, 64))}
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1
    # a stale tmp dir must never be listed as a checkpoint
    os.makedirs(str(tmp_path / "step_99.tmp"), exist_ok=True)
    assert 99 not in ck.steps()


def test_supervisor_recovers_from_injected_failure(tmp_path):
    cfg = C.get_config("qwen3-0.6b", reduced=True)
    opt = AdamW(lr=1e-3)
    state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
    step_jit = jax.jit(TL.make_train_step(cfg, opt))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=2)

    ck = Checkpointer(str(tmp_path), keep=2)
    sup = Supervisor(ck, max_restarts=2, checkpoint_every=4)
    inj = FailureInjector(fail_at_steps=(6,))
    seen = []

    def step_fn(state, step):
        seen.append(step)
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        return step_jit(state, batch)

    state, step = sup.run_resilient(state, step_fn, 10, injector=inj,
                                    on_metrics=lambda *a: None)
    assert step == 10
    assert sup.restarts == 1
    assert 4 in seen and seen.count(5) >= 2   # replayed from checkpoint 4


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(str(tmp_path))
    sup = Supervisor(ck, max_restarts=1, checkpoint_every=100)

    def bad_step(state, step):
        raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        sup.run_resilient({}, bad_step, 5)


def test_straggler_detector():
    det = StragglerDetector(threshold=2.0, warmup=1)
    for i in range(5):
        assert not det.observe(i, 0.1)
    assert det.observe(5, 0.5)
    assert len(det.flagged) == 1
    # EWMA not polluted by the straggler
    assert abs(det.ewma - 0.1) < 1e-6


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(512, 16) == (32, 16)
    assert elastic_mesh_shape(496, 16) == (31, 16)   # one host lost
    with pytest.raises(AssertionError):
        elastic_mesh_shape(8, 16)


def test_compression_roundtrip_convergence():
    """EF compression must not change AdamW convergence direction."""
    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    params = {"w": jnp.zeros((16,))}
    opt = AdamW(lr=0.05, weight_decay=0.0)
    state = opt.init(params)
    ef = compression.init_ef(params)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - w_true)}
        grads, ef = compression.compress_grads(grads, ef)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"] - w_true))) < 0.05


def test_grad_accumulation_equivalence():
    """accum=2 over a batch must match accum=1 on the same batch."""
    cfg = C.get_config("qwen3-0.6b", reduced=True)
    opt = AdamW(lr=1e-3)
    state = TL.init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4)
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))

    s1, m1 = TL.make_train_step(cfg, opt, accum=1)(state, batch)
    s2, m2 = TL.make_train_step(cfg, opt, accum=2)(state, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_mamba_prefill_decode_state_equivalence(rng):
    """mamba_apply(return_state) then mamba_decode == full mamba_apply."""
    cfg = C.get_config("mamba2-2.7b", reduced=True)
    key = jax.random.PRNGKey(0)
    p = S.mamba_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(2, 48, cfg.d_model)) * 0.1, jnp.float32)
    full, _ = S.mamba_apply(p, x, cfg)
    out_pre, st = S.mamba_apply(p, x[:, :32], cfg, return_state=True)
    out_dec, _ = S.mamba_decode(p, x[:, 32:33], cfg, st)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(full[:, 32:33]),
                               rtol=2e-3, atol=2e-3)
