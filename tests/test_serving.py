"""Continuous-batching engine: decode token-exactness vs whole-prompt
prefill, mid-stream admission, scheduler FCFS, and the samplers.

The equivalence oracle is the degenerate single-request path: one
batch-1 prefill over the whole prompt followed by scalar-pos lock-step
decode. The engine — bucketed prefill + per-slot vector-pos decode over
a shared slot pool, with requests admitted mid-stream into freed slots
— must emit exactly the same greedy tokens per request.

MoE archs are deliberately absent: expert capacity is contended by
whichever tokens share a decode batch, so continuous batching is not
token-exact vs an isolated run by construction (see serving/engine.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving import SamplerConfig, ServingEngine, SlotScheduler, \
    make_sampler
from repro.serving.request import Request


def _reference_generate(cfg, params, prompt, n_new, enc=None):
    """Whole-prompt prefill + scalar-pos greedy decode, batch 1."""
    L = len(prompt)
    a = cfg.attn_chunk
    max_len = L + n_new
    if max_len > a and max_len % a:    # same rounding as the engine
        max_len += a - max_len % a
    cache = M.init_cache(cfg, 1, max_len)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if enc is not None:
        batch["enc_frames"] = jnp.asarray(enc[None])
    logits, cache = M.prefill(cfg, params, batch, cache)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab]))]
    for i in range(n_new - 1):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = M.decode_step(cfg, params, tok, jnp.int32(L + i),
                                      cache)
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    return toks


def _run_engine(cfg, params, prompts, gens, max_slots, max_len, encs=None):
    eng = ServingEngine(cfg, params, max_slots=max_slots, max_len=max_len)
    encs = encs or [None] * len(prompts)
    reqs = [eng.submit(p, g, enc_frames=e)
            for p, g, e in zip(prompts, gens, encs)]
    report = eng.run()
    return eng, reqs, report


# prompt length 13 exercises the bucket-remainder (tail-decode) prefill
CASES = {
    "qwen3-0.6b": [8, 24, 13, 40],    # dense, GQA + qk-norm, RoPE
    "qwen2-vl-2b": [8, 16, 13, 24],   # vlm, M-RoPE degenerate text path
    "mamba2-2.7b": [8, 24, 16, 32],   # ssm, recurrent-state slot copy
}
GENS = [5, 4, 7, 6]


@pytest.mark.parametrize("arch", sorted(CASES))
def test_engine_decode_matches_whole_prompt_prefill(arch):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES[arch]]

    # 4 requests over 2 slots: requests 2 and 3 are admitted mid-stream,
    # into slots freed while the other slot keeps decoding.
    eng, reqs, report = _run_engine(cfg, params, prompts, GENS,
                                    max_slots=2, max_len=64)

    assert report["n_finished"] == len(reqs)
    admitted = sorted(r.t_admitted for r in reqs)
    finished = sorted(r.t_finished for r in reqs)
    assert admitted[-1] > finished[0], "expected a mid-stream admission"

    for req, prompt, g in zip(reqs, prompts, GENS):
        want = _reference_generate(cfg, params, prompt, g)
        assert req.generated == want, (arch, req.rid, req.generated, want)
        assert all(0 <= t < cfg.vocab for t in req.generated)


def test_engine_encdec_with_cross_cache_slots():
    cfg = get_config("whisper-tiny", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    lengths = [8, 16, 11]
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in lengths]
    encs = [rng.normal(size=(cfg.enc_ctx, cfg.d_model)).astype(np.float32)
            for _ in lengths]
    eng, reqs, _ = _run_engine(cfg, params, prompts, [4, 3, 5],
                               max_slots=2, max_len=32, encs=encs)
    for req, prompt, g, enc in zip(reqs, prompts, [4, 3, 5], encs):
        assert req.generated == _reference_generate(cfg, params, prompt, g,
                                                    enc)


def test_vector_pos_uniform_batch_matches_scalar():
    """All slots at the same depth: the per-slot vector path must equal
    the scalar lock-step path bit-for-bit (degenerate case)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    cache = M.init_cache(cfg, B, 24)
    logits, cache = M.prefill(cfg, params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg_s, c_s = M.decode_step(cfg, params, tok, jnp.int32(S), cache)
    lg_v, c_v = M.decode_step(cfg, params, tok,
                              jnp.full((B,), S, jnp.int32), cache)
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_inactive_slot_leaves_cache_untouched():
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 8
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    cache = M.init_cache(cfg, B, 16)
    _, cache = M.prefill(cfg, params, batch, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.asarray([S, -1], jnp.int32)    # slot 1 inactive
    _, new_cache = M.decode_step(cfg, params, tok, pos, cache)
    for old, new in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        np.testing.assert_array_equal(np.asarray(old[:, 1]),
                                      np.asarray(new[:, 1]))


def test_engine_flash_decode_token_exact_pallas():
    """Serving under a pallas policy: every single-token step must route
    through the flash_decode kernel (spied at the kernel module), and
    the engine — bucketed prefill + per-slot vector-pos decode + a
    mid-stream admission — must emit exactly the reference tokens
    computed under the SAME policy (whole-prompt prefill + scalar-pos
    lock-step decode), i.e. the batching machinery adds nothing."""
    from repro.core.policy import Policy
    from repro.kernels import flash_attention as fa

    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(backend="pallas", interpret=True)
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES["qwen3-0.6b"]]

    calls = []
    orig = fa.flash_decode

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return orig(*a, **kw)

    fa.flash_decode = spy
    try:
        eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                            policy=pol)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, GENS)]
        report = eng.run()
    finally:
        fa.flash_decode = orig

    assert report["n_finished"] == len(reqs)
    assert calls, "pallas-policy decode never reached the flash kernel"
    assert all(shape[1] == 1 for shape in calls)   # q_len=1 by contract
    admitted = sorted(r.t_admitted for r in reqs)
    finished = sorted(r.t_finished for r in reqs)
    assert admitted[-1] > finished[0], "expected a mid-stream admission"

    with pol.scope():
        for req, prompt, g in zip(reqs, prompts, GENS):
            want = _reference_generate(cfg, params, prompt, g)
            assert req.generated == want, (req.rid, req.generated, want)


def test_scheduler_fcfs_and_release():
    sched = SlotScheduler(2)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                    arrival_time=float(i)) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert sched.next_admission(now=0.5) is reqs[0]
    sched.admit(reqs[0])
    # FCFS: head (rid 1) hasn't arrived yet -> nothing, even though rid 2
    # would not fit anyway; at t=1.0 the head goes in.
    assert sched.next_admission(now=0.5) is None
    assert sched.next_admission(now=1.0) is reqs[1]
    sched.admit(reqs[1])
    assert sched.next_admission(now=5.0) is None      # no free slot
    sched.release(reqs[0].slot)
    assert sched.next_admission(now=5.0) is reqs[2]
    assert sched.n_free == 1 and sched.n_waiting == 1 and sched.n_active == 1


def test_engine_rounds_max_len_to_attn_chunk():
    """max_len is trace-dependent; a length in (attn_chunk, 2*attn_chunk)
    that is not a chunk multiple must be rounded up, not crash decode."""
    cfg = get_config("qwen3-0.6b", reduced=True)   # attn_chunk = 64
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=1, max_len=86)
    assert eng.max_len == 128
    prompt = np.arange(60, dtype=np.int32) % cfg.vocab
    req = eng.submit(prompt, 10)                   # decodes past pos 64
    eng.run()
    assert req.generated == _reference_generate(cfg, params, prompt, 10)


def test_samplers():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(64,)).astype(np.float32)
    greedy = make_sampler("greedy")
    assert greedy(logits) == int(np.argmax(logits))
    # top_k >= vocab degenerates to full-vocab sampling, no crash
    assert 0 <= make_sampler("temperature", top_k=100)(logits) < 64
    # temperature + top-k: support restricted to the k best logits
    topk = make_sampler("temperature", temperature=0.8, top_k=4, seed=1)
    allowed = set(np.argsort(logits)[-4:].tolist())
    assert all(topk(logits) in allowed for _ in range(32))
    # same seed -> same trace
    s1 = make_sampler("temperature", seed=5)
    s2 = make_sampler("temperature", seed=5)
    assert [s1(logits) for _ in range(8)] == [s2(logits) for _ in range(8)]
    with pytest.raises(ValueError):
        SamplerConfig(kind="nucleus")
    with pytest.raises(ValueError):
        SamplerConfig(kind="temperature", temperature=0.0)


def test_serve_cli_mixed_trace_smoke():
    from repro.launch.serve import main as serve_main
    report = serve_main(["--reduced", "--requests", "5", "--max-slots", "2",
                         "--gen", "4", "--prompt-len-min", "8",
                         "--prompt-len-max", "20", "--arrival-rate", "0"])
    assert report["n_finished"] == 5
    assert report["mean_occupancy"] <= 2.0
