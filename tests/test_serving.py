"""Continuous-batching engine: decode token-exactness vs whole-prompt
prefill, mid-stream admission, scheduler FCFS, and the samplers.

The equivalence oracle is the degenerate single-request path: one
batch-1 prefill over the whole prompt followed by scalar-pos lock-step
decode. The engine — bucketed prefill + per-slot vector-pos decode over
a shared slot pool, with requests admitted mid-stream into freed slots
— must emit exactly the same greedy tokens per request.

MoE archs are deliberately absent: expert capacity is contended by
whichever tokens share a decode batch, so continuous batching is not
token-exact vs an isolated run by construction (see serving/engine.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving import SamplerConfig, ServingEngine, SlotScheduler, \
    make_sampler
from repro.serving.request import Request


def _reference_generate(cfg, params, prompt, n_new, enc=None):
    """Whole-prompt prefill + scalar-pos greedy decode, batch 1."""
    L = len(prompt)
    a = cfg.attn_chunk
    max_len = L + n_new
    if max_len > a and max_len % a:    # same rounding as the engine
        max_len += a - max_len % a
    cache = M.init_cache(cfg, 1, max_len)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if enc is not None:
        batch["enc_frames"] = jnp.asarray(enc[None])
    logits, cache = M.prefill(cfg, params, batch, cache)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab]))]
    for i in range(n_new - 1):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = M.decode_step(cfg, params, tok, jnp.int32(L + i),
                                      cache)
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    return toks


def _run_engine(cfg, params, prompts, gens, max_slots, max_len, encs=None):
    eng = ServingEngine(cfg, params, max_slots=max_slots, max_len=max_len)
    encs = encs or [None] * len(prompts)
    reqs = [eng.submit(p, g, enc_frames=e)
            for p, g, e in zip(prompts, gens, encs)]
    report = eng.run()
    return eng, reqs, report


# prompt length 13 exercises the bucket-remainder (tail-decode) prefill
CASES = {
    "qwen3-0.6b": [8, 24, 13, 40],    # dense, GQA + qk-norm, RoPE
    "qwen2-vl-2b": [8, 16, 13, 24],   # vlm, M-RoPE degenerate text path
    "mamba2-2.7b": [8, 24, 16, 32],   # ssm, recurrent-state slot copy
}
GENS = [5, 4, 7, 6]


@pytest.mark.parametrize("arch", sorted(CASES))
def test_engine_decode_matches_whole_prompt_prefill(arch):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES[arch]]

    # 4 requests over 2 slots: requests 2 and 3 are admitted mid-stream,
    # into slots freed while the other slot keeps decoding.
    eng, reqs, report = _run_engine(cfg, params, prompts, GENS,
                                    max_slots=2, max_len=64)

    assert report["n_finished"] == len(reqs)
    admitted = sorted(r.t_admitted for r in reqs)
    finished = sorted(r.t_finished for r in reqs)
    assert admitted[-1] > finished[0], "expected a mid-stream admission"

    for req, prompt, g in zip(reqs, prompts, GENS):
        want = _reference_generate(cfg, params, prompt, g)
        assert req.generated == want, (arch, req.rid, req.generated, want)
        assert all(0 <= t < cfg.vocab for t in req.generated)


def test_engine_encdec_with_cross_cache_slots():
    cfg = get_config("whisper-tiny", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    lengths = [8, 16, 11]
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in lengths]
    encs = [rng.normal(size=(cfg.enc_ctx, cfg.d_model)).astype(np.float32)
            for _ in lengths]
    eng, reqs, _ = _run_engine(cfg, params, prompts, [4, 3, 5],
                               max_slots=2, max_len=32, encs=encs)
    for req, prompt, g, enc in zip(reqs, prompts, [4, 3, 5], encs):
        assert req.generated == _reference_generate(cfg, params, prompt, g,
                                                    enc)


def test_vector_pos_uniform_batch_matches_scalar():
    """All slots at the same depth: the per-slot vector path must equal
    the scalar lock-step path bit-for-bit (degenerate case)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    cache = M.init_cache(cfg, B, 24)
    logits, cache = M.prefill(cfg, params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg_s, c_s = M.decode_step(cfg, params, tok, jnp.int32(S), cache)
    lg_v, c_v = M.decode_step(cfg, params, tok,
                              jnp.full((B,), S, jnp.int32), cache)
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_inactive_slot_leaves_cache_untouched():
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 8
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    cache = M.init_cache(cfg, B, 16)
    _, cache = M.prefill(cfg, params, batch, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.asarray([S, -1], jnp.int32)    # slot 1 inactive
    _, new_cache = M.decode_step(cfg, params, tok, pos, cache)
    for old, new in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        np.testing.assert_array_equal(np.asarray(old[:, 1]),
                                      np.asarray(new[:, 1]))


def test_engine_flash_decode_token_exact_pallas():
    """Serving under a pallas policy: every single-token step must route
    through the flash_decode kernel (spied at the kernel module), and
    the engine — bucketed prefill + per-slot vector-pos decode + a
    mid-stream admission — must emit exactly the reference tokens
    computed under the SAME policy (whole-prompt prefill + scalar-pos
    lock-step decode), i.e. the batching machinery adds nothing."""
    from repro.core.policy import Policy
    from repro.kernels import flash_attention as fa

    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(backend="pallas", interpret=True)
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES["qwen3-0.6b"]]

    calls = []
    orig = fa.flash_decode

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return orig(*a, **kw)

    fa.flash_decode = spy
    try:
        eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                            policy=pol)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, GENS)]
        report = eng.run()
    finally:
        fa.flash_decode = orig

    assert report["n_finished"] == len(reqs)
    assert calls, "pallas-policy decode never reached the flash kernel"
    assert all(shape[1] == 1 for shape in calls)   # q_len=1 by contract
    admitted = sorted(r.t_admitted for r in reqs)
    finished = sorted(r.t_finished for r in reqs)
    assert admitted[-1] > finished[0], "expected a mid-stream admission"

    with pol.scope():
        for req, prompt, g in zip(reqs, prompts, GENS):
            want = _reference_generate(cfg, params, prompt, g)
            assert req.generated == want, (req.rid, req.generated, want)


def test_engine_ssd_token_exact_pallas():
    """Serving mamba2 under a pallas policy: every prefill must route
    through the ssd_pallas kernel via the ("ssd", "pallas") registry
    entry (spied at the kernel module — the registered impl looks the
    symbol up at call time), and the engine must emit exactly the
    reference tokens computed under the SAME policy."""
    from repro.core.policy import Policy
    from repro.kernels import ssd as ssd_mod

    cfg = get_config("mamba2-2.7b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(backend="pallas", interpret=True)
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES["mamba2-2.7b"]]

    calls = []
    orig = ssd_mod.ssd_pallas

    def spy(x, *a, **kw):
        calls.append(x.shape)
        return orig(x, *a, **kw)

    ssd_mod.ssd_pallas = spy
    try:
        eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                            policy=pol)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, GENS)]
        report = eng.run()
    finally:
        ssd_mod.ssd_pallas = orig

    assert report["n_finished"] == len(reqs)
    assert calls, "pallas-policy prefill never reached the SSD kernel"
    assert all(len(shape) == 4 for shape in calls)   # (B, L, H, P) contract

    with pol.scope():
        for req, prompt, g in zip(reqs, prompts, GENS):
            want = _reference_generate(cfg, params, prompt, g)
            assert req.generated == want, (req.rid, req.generated, want)


def test_engine_short_prompt_conv_tail():
    """The conv-state bug this PR fixed: a prompt SHORTER than
    conv_width - 1 used to yield a mis-shaped conv-state tail from
    mamba_apply(return_state=True). Such prompts must admit cleanly
    through the engine and decode token-exactly vs the reference."""
    cfg = get_config("mamba2-2.7b", reduced=True)   # conv_width = 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    w1 = cfg.ssm.conv_width - 1
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in (1, w1 - 1, w1, 8)]
    gens = [4, 4, 4, 4]
    eng, reqs, report = _run_engine(cfg, params, prompts, gens,
                                    max_slots=2, max_len=32)
    assert report["n_finished"] == len(reqs)
    for req, prompt, g in zip(reqs, prompts, gens):
        want = _reference_generate(cfg, params, prompt, g)
        assert req.generated == want, (len(prompt), req.generated, want)


def test_scheduler_fcfs_and_release():
    sched = SlotScheduler(2)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                    arrival_time=float(i)) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert sched.next_admission(now=0.5) is reqs[0]
    sched.admit(reqs[0])
    # FCFS: head (rid 1) hasn't arrived yet -> nothing, even though rid 2
    # would not fit anyway; at t=1.0 the head goes in.
    assert sched.next_admission(now=0.5) is None
    assert sched.next_admission(now=1.0) is reqs[1]
    sched.admit(reqs[1])
    assert sched.next_admission(now=5.0) is None      # no free slot
    sched.release(reqs[0].slot)
    assert sched.next_admission(now=5.0) is reqs[2]
    assert sched.n_free == 1 and sched.n_waiting == 1 and sched.n_active == 1


def test_engine_rounds_max_len_to_attn_chunk():
    """max_len is trace-dependent; a length in (attn_chunk, 2*attn_chunk)
    that is not a chunk multiple must be rounded up, not crash decode."""
    cfg = get_config("qwen3-0.6b", reduced=True)   # attn_chunk = 64
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=1, max_len=86)
    assert eng.max_len == 128
    prompt = np.arange(60, dtype=np.int32) % cfg.vocab
    req = eng.submit(prompt, 10)                   # decodes past pos 64
    eng.run()
    assert req.generated == _reference_generate(cfg, params, prompt, 10)


def test_samplers():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(64,)).astype(np.float32)
    greedy = make_sampler("greedy")
    assert greedy(logits) == int(np.argmax(logits))
    # top_k >= vocab degenerates to full-vocab sampling, no crash
    assert 0 <= make_sampler("temperature", top_k=100)(logits) < 64
    # temperature + top-k: support restricted to the k best logits
    topk = make_sampler("temperature", temperature=0.8, top_k=4, seed=1)
    allowed = set(np.argsort(logits)[-4:].tolist())
    assert all(topk(logits) in allowed for _ in range(32))
    # same seed -> same trace
    s1 = make_sampler("temperature", seed=5)
    s2 = make_sampler("temperature", seed=5)
    assert [s1(logits) for _ in range(8)] == [s2(logits) for _ in range(8)]
    with pytest.raises(ValueError):
        SamplerConfig(kind="nucleus")
    with pytest.raises(ValueError):
        SamplerConfig(kind="temperature", temperature=0.0)


# ------------------------------------------------------- paged KV cache


def test_kv_pool_prefix_sharing_refcounts_and_release():
    from repro.serving import KVPagePool
    pool = KVPagePool(n_pages=16, page_size=4, max_slots=4,
                      pages_per_slot=4)
    prompt = np.arange(12, dtype=np.int32)          # 3 full pages
    p0 = pool.admit_slot(0, prompt, 4)
    assert len(p0.private) == 3 and not p0.shared
    p1 = pool.admit_slot(1, prompt, 4)
    assert len(p1.shared) == 3 and not p1.private   # whole prompt shared
    for _, phys in p1.shared:
        assert pool.refcount[phys] == 2
    assert pool.sharing_ratio() == 2.0
    pool.release_slot(0)
    for _, phys in p1.shared:
        assert pool.refcount[phys] == 1             # survivor keeps pages
    pool.release_slot(1)
    assert (pool.refcount == 0).all()
    assert pool.n_free == pool.n_pages and pool.n_reserved == 0
    assert (pool.table == -1).all()
    assert not pool._by_hash and not pool._hash_of  # registry drained


def test_kv_pool_copy_on_write_preserves_sharer():
    from repro.serving import KVPagePool
    pool = KVPagePool(n_pages=16, page_size=4, max_slots=4,
                      pages_per_slot=4)
    prompt = np.arange(10, dtype=np.int32)          # 2 full + partial tail
    pool.admit_slot(0, prompt, 4)
    plan = pool.admit_slot(1, prompt, 4)
    tail = dict(plan.shared)[2]                     # shared partial page
    assert pool.refcount[tail] == 2
    # first generated token (pos 10) lands in the shared tail page -> CoW
    w = pool.prepare_write(1, 10)
    assert w is not None and w.kind == "cow"
    assert w.src == tail and w.dst != tail
    assert pool.table[1, 2] == w.dst                # writer retargeted
    assert pool.table[0, 2] == tail                 # sharer untouched
    assert pool.refcount[tail] == 1
    assert pool.stats.cow_copies == 1
    # subsequent writes into now-private pages need no directive
    assert pool.prepare_write(1, 11) is None
    assert pool.prepare_write(0, 10) is None
    # a write past the mapped range allocates a fresh page
    w2 = pool.prepare_write(1, 12)
    assert w2.kind == "alloc" and pool.table[1, 3] == w2.dst


def test_kv_pool_exhaustion_refuses_cleanly():
    from repro.serving import KVPagePool, KVPoolExhausted
    pool = KVPagePool(n_pages=2, page_size=4, max_slots=2,
                      pages_per_slot=4)
    pool.admit_slot(0, np.arange(4, dtype=np.int32), 4)  # 1 page + 1 rsvd
    assert not pool.can_admit(np.arange(8, dtype=np.int32), 4)
    with pytest.raises(KVPoolExhausted):
        pool.admit_slot(1, np.arange(8, dtype=np.int32), 4)
    assert pool.stats.refused == 1
    # refusal leaves state intact: slot 0's reservation still honored
    assert pool.prepare_write(0, 4).kind == "alloc"
    pool.release_slot(0)
    assert pool.n_free == pool.n_pages


def test_engine_cow_copies_bytes_and_leaves_shared_page_intact():
    """Two identical prompts share a partial tail page; the first decode
    step CoWs it for one writer. The copy must carry the prefix rows and
    the original page must keep serving the other slot byte-for-byte."""
    from repro.core.policy import Policy
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(kv_layout="paged")
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32, policy=pol,
                        page_size=8)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    r0 = eng.submit(prompt.copy(), 4)
    r1 = eng.submit(prompt.copy(), 4)
    eng.step()          # admits both (tail page shared), decodes pos 10
    assert eng.pool.stats.cow_copies == 1
    pa, pb = int(eng.pool.table[0, 1]), int(eng.pool.table[1, 1])
    assert pa != pb     # tail page diverged
    # prefix rows (pos 8, 9) identical across original and CoW copy, in
    # every layer of both pools
    for name in ("k", "v"):
        pages = np.asarray(eng.cache["pages"][name])
        np.testing.assert_array_equal(pages[:, pa, :2], pages[:, pb, :2])
    eng.run()
    want = _reference_generate(cfg, params, prompt, 4)
    assert r0.generated == want and r1.generated == want


def test_engine_paged_pool_deferral_and_submit_refusal():
    from repro.core.policy import Policy
    from repro.serving import KVPoolExhausted
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(kv_layout="paged")
    eng = ServingEngine(cfg, params, max_slots=3, max_len=64, policy=pol,
                        page_size=8, kv_pool_pages=6)
    # a request that fits max_len but can never fit the 6-page pool is
    # refused at submit, not queued
    with pytest.raises(KVPoolExhausted):
        eng.submit(np.arange(50, dtype=np.int32) % cfg.vocab, 10)
    # three requests whose pages exceed the pool: the third waits for a
    # release even though a scheduler slot is free the whole time
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (24,)).astype(np.int32)
               for _ in range(3)]
    reqs = [eng.submit(p, 6) for p in prompts]
    report = eng.run()
    assert report["n_finished"] == 3
    admitted = sorted(r.t_admitted for r in reqs)
    finished = sorted(r.t_finished for r in reqs)
    assert admitted[-1] > finished[0], "expected a pool-deferred admission"
    for req, prompt in zip(reqs, prompts):
        assert req.generated == _reference_generate(cfg, params, prompt, 6)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen2-vl-2b"])
def test_engine_paged_int8_token_exact_pallas(arch):
    """Paged + int8-KV serving under the pallas policy must route every
    decode step through the paged flash kernel (spied) and emit exactly
    the tokens of the dense full-precision whole-prompt reference."""
    from repro.core.policy import Policy
    from repro.kernels import flash_attention as fa

    cfg = get_config(arch, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pol = Policy(backend="pallas", interpret=True,
                 kv_layout="paged", quant_kv="int8")
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in CASES[arch]]

    calls = []
    orig = fa.flash_decode_paged

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return orig(*a, **kw)

    fa.flash_decode_paged = spy
    try:
        eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                            policy=pol, page_size=8)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, GENS)]
        report = eng.run()
    finally:
        fa.flash_decode_paged = orig

    assert report["n_finished"] == len(reqs)
    assert calls, "paged decode never reached the paged flash kernel"
    # kernel-level q is (batch, heads, head_dim): q_len already squeezed
    assert all(len(shape) == 3 for shape in calls)
    assert report["kv_pool"]["cow_copies"] >= 0    # pool report wired up

    ref_pol = Policy(backend="pallas", interpret=True)   # dense f32 KV
    with ref_pol.scope():
        for req, prompt, g in zip(reqs, prompts, GENS):
            want = _reference_generate(cfg, params, prompt, g)
            assert req.generated == want, (arch, req.rid, req.generated,
                                           want)


def test_engine_paged_rejects_unsupported_combinations():
    from repro.core.policy import Policy
    cfg = get_config("mamba2-2.7b", reduced=True)    # ssm: no KV pages
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_slots=2, max_len=32,
                      policy=Policy(kv_layout="paged"))
    cfg2 = get_config("qwen3-0.6b", reduced=True)
    params2 = M.init_params(cfg2, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):                  # int8 KV needs pages
        ServingEngine(cfg2, params2, max_slots=2, max_len=32,
                      policy=Policy(quant_kv="int8"))


def test_serve_cli_mixed_trace_smoke():
    from repro.launch.serve import main as serve_main
    report = serve_main(["--reduced", "--requests", "5", "--max-slots", "2",
                         "--gen", "4", "--prompt-len-min", "8",
                         "--prompt-len-max", "20", "--arrival-rate", "0"])
    assert report["n_finished"] == 5
    assert report["mean_occupancy"] <= 2.0


def test_scheduler_and_request_validation_errors():
    """Bare asserts became ValueErrors that NAME the offender: bad
    arguments fail with an actionable message, not an AssertionError."""
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 100, (8,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_slots"):
        SlotScheduler(0)
    with pytest.raises(ValueError, match="request .*: empty prompt"):
        Request(rid=3, prompt=np.empty((0,), np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid=4, prompt=prompt, max_new_tokens=0)
    with pytest.raises(ValueError, match="deadline"):
        Request(rid=5, prompt=prompt, max_new_tokens=4,
                arrival_time=2.0, deadline=1.0)

    sched = SlotScheduler(1)
    req = Request(rid=0, prompt=prompt, max_new_tokens=4)
    sched.submit(req)
    with pytest.raises(ValueError, match="request 0"):   # double submit
        sched.submit(req)
    sched.admit(req)
    with pytest.raises(ValueError, match="request 0"):   # not waiting
        sched.admit(req)
    with pytest.raises(ValueError, match="slot 7"):
        sched.release(7)
    with pytest.raises(ValueError, match="slot 5.*preempt"):
        sched.preempt(5, resume_at=0.0)
    sched.release(req.slot)
    with pytest.raises(ValueError, match="slot 0"):      # double release
        sched.release(0)


def test_kv_pool_release_during_cow_and_double_release():
    """Satellite: releasing a CoW participant mid-divergence leaves the
    survivor's mapping and refcounts intact; slot-level double release
    is a no-op while a page-level double release fails loudly."""
    from repro.serving import KVPagePool
    pool = KVPagePool(n_pages=16, page_size=4, max_slots=4,
                      pages_per_slot=4)
    prompt = np.arange(10, dtype=np.int32)     # 2 full pages + partial tail
    pool.admit_slot(0, prompt, 4)
    plan = pool.admit_slot(1, prompt, 4)
    tail = dict(plan.shared)[2]
    w = pool.prepare_write(1, 10)              # slot 1 CoWs the tail page
    assert w.kind == "cow" and pool.refcount[tail] == 1
    # release the ORIGINAL owner right after the split: the writer's
    # fully-shared prefix pages survive, its private CoW page survives
    pool.release_slot(0)
    for j in (0, 1):
        assert pool.refcount[pool.table[1, j]] == 1
    assert pool.refcount[w.dst] == 1 and pool.refcount[tail] == 0
    assert pool.table[1, 2] == w.dst
    # the survivor keeps writing into its now-private mapping
    assert pool.prepare_write(1, 11) is None
    # slot-level double release: table row already cleared -> no-op
    pool.release_slot(0)
    pool.release_slot(1)
    assert (pool.refcount == 0).all() and pool.n_free == pool.n_pages
    pool.release_slot(1)                       # still a no-op
    # page-level double release means table/refcount divergence: loud
    with pytest.raises(ValueError, match="double release of page"):
        pool._release_page(w.dst)


def test_engine_release_during_cow_device_bytes_intact():
    """Device-checked: cancelling the CoW *survivor's sharer* right
    after the split must not disturb the surviving slot's page bytes —
    its prefix rows still equal the released slot's original page."""
    from repro.core.policy import Policy
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32,
                        policy=Policy(kv_layout="paged"), page_size=8)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    r0 = eng.submit(prompt.copy(), 4)
    r1 = eng.submit(prompt.copy(), 4)
    eng.step()                                 # tail page CoW'd for slot 1
    assert eng.pool.stats.cow_copies == 1
    pa, pb = int(eng.pool.table[0, 1]), int(eng.pool.table[1, 1])
    before = {n: np.asarray(eng.cache["pages"][n])[:, pb].copy()
              for n in ("k", "v")}
    assert eng.cancel(r0.rid)                  # release slot 0 mid-CoW
    for n in ("k", "v"):                       # survivor's page untouched
        np.testing.assert_array_equal(
            np.asarray(eng.cache["pages"][n])[:, pb], before[n])
    eng.run()
    assert r1.generated == _reference_generate(cfg, params, prompt, 4)
    assert (eng.pool.refcount == 0).all()
    _ = pa                                     # slot 0's page, now freed


def test_workload_bursty_deadlines_priorities():
    from repro.serving import TraceItem, synthetic_trace
    from repro.serving.workload import _arrivals
    cfg = get_config("qwen3-0.6b", reduced=True)
    rng = np.random.default_rng(0)
    trace = synthetic_trace(cfg, 12, rng=rng, len_range=(8, 16), gen=4,
                            arrival_rate=8.0, deadline=2.5,
                            priority_levels=(0, 1, 2), burst_size=4)
    assert all(isinstance(it, TraceItem) for it in trace)
    arr = np.array([it.arrival for it in trace])
    # bursty: groups of 4 arrive at the SAME instant, gaps between groups
    assert len(np.unique(arr)) == 3
    assert (np.diff(arr) >= 0).all()
    # deadline is stored ABSOLUTE (arrival + relative)
    assert all(abs(it.deadline - (it.arrival + 2.5)) < 1e-12
               for it in trace)
    assert {it.priority for it in trace} <= {0, 1, 2}
    # long-run rate preserved: burst gaps scale with the group size
    rng2 = np.random.default_rng(1)
    smooth = _arrivals(rng2, 4000, 8.0, 1)
    rng3 = np.random.default_rng(1)
    bursty = _arrivals(rng3, 4000, 8.0, 4)
    assert abs(smooth[-1] / bursty[-1] - 1.0) < 0.15
    with pytest.raises(ValueError, match="burst_size"):
        synthetic_trace(cfg, 4, rng=rng, burst_size=0)
    with pytest.raises(ValueError, match="priority_levels"):
        synthetic_trace(cfg, 4, rng=rng, priority_levels=())
