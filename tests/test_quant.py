"""Quantized int8 GEMM path (ISSUE 5): quantize/dequantize error
bounds, dense_q forward + VJP parity against the dequantized f32
composition, fingerprint/cache-key separation (incl. the pre-existing
tuning.json back-compat contract), registry error paths for the new op,
param-tree quantization, engine integration, warm_start coverage, and
the modeled HBM-byte saving."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import blocking, gemm
from repro.core import policy as pol_mod
from repro.core import precision
from repro.core.policy import Policy
from repro.kernels import ops, registry
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models import model as M
from repro.roofline import analysis
from repro.tuning import autotuner as AT
from repro.tuning import cache as TC

_PI = Policy(backend="pallas", interpret=True)
_XLA = Policy()


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _quantized(rng, k, n, dtype=jnp.float32):
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    wq, scale = precision.quantize_int8(w)
    return w, wq, scale


# ----------------------------------------------------------------------
# quantize / dequantize round trip
# ----------------------------------------------------------------------

@pytest.mark.parametrize("shape,axis_shape", [
    ((33, 17), (1, 17)),          # dense weight
    ((128, 256), (1, 256)),
    ((4, 9, 6), (4, 1, 6)),       # scanned stack (per layer x channel)
])
def test_roundtrip_error_within_grid_bound(rng, shape, axis_shape):
    """|dequantize(quantize(w)) - w| <= scale/2 per element: round-to-
    nearest on the symmetric grid, and amax/127 puts the per-channel
    extreme exactly on the grid (no clipping error)."""
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    q, scale = precision.quantize_int8(w)
    assert q.dtype == jnp.int8 and scale.shape == axis_shape
    err = jnp.abs(precision.dequantize(q, scale) - w)
    bound = jnp.broadcast_to(precision.quant_error_bound(scale), shape)
    assert bool(jnp.all(err <= bound + 1e-7))
    # extremes representable exactly: |q| reaches 127, never clips past
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) == 127


def test_roundtrip_from_bf16_and_zero_channel(rng):
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.bfloat16)
    w = w.at[:, 3].set(jnp.zeros((16,), jnp.bfloat16))   # dead channel
    q, scale = precision.quantize_int8(w)
    assert float(scale[0, 3]) == 1.0          # guarded, not div-by-zero
    assert bool(jnp.all(q[:, 3] == 0))
    err = jnp.abs(precision.dequantize(q, scale) - w.astype(jnp.float32))
    assert bool(jnp.all(err <= precision.quant_error_bound(scale) + 1e-6))


def test_quantspec_validation_and_mode_tuples_pinned():
    import types
    with pytest.raises(ValueError, match="int8"):
        precision.QuantSpec(mode="int4")
    with pytest.raises(ValueError, match="int8"):
        precision.quantize(jnp.ones((4, 4)),
                           types.SimpleNamespace(mode="fp8", axis=-2))
    # Policy-level modes = {"off"} + precision-level modes
    assert set(pol_mod.QUANT_MODES) == {"off", *precision.QUANT_MODES}


# ----------------------------------------------------------------------
# dense_q forward parity vs the dequantized f32 composition
# ----------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (33, 17, 29), (1, 40, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_q_matches_dequantized_dense(rng, m, k, n, dtype):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w, wq, scale = _quantized(rng, k, n)
    want = np.asarray(gemm.dense(
        x, precision.dequantize(wq, scale).astype(dtype),
        policy=_XLA).astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    for pol in (_XLA, _PI, Policy(backend="naive", interpret=True)):
        got = np.asarray(gemm.dense_q(x, wq, scale,
                                      policy=pol).astype(jnp.float32))
        np.testing.assert_allclose(
            got, want, atol=tol * max(np.abs(want).max(), 1.0), rtol=0,
            err_msg=str(pol.backend))


@pytest.mark.parametrize("activation,residual", [
    ("gelu", False), ("silu", False), (None, True), (None, False)])
def test_dense_q_epilogues_fused_vs_unfused(rng, activation, residual):
    """The fused flush (pallas) and the unfused composition
    (fuse_epilogues=False) compute the same function — the quantized
    kernel composes with the whole epilogue lattice."""
    x = jnp.asarray(rng.normal(size=(2, 9, 24)), jnp.float32)
    w, wq, scale = _quantized(rng, 24, 16)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(2, 9, 16)), jnp.float32) \
        if residual else None
    fused = gemm.dense_q(x, wq, scale, b, activation=activation,
                         residual=r, policy=_PI)
    unfused = gemm.dense_q(x, wq, scale, b, activation=activation,
                           residual=r,
                           policy=_PI.replace(fuse_epilogues=False))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


def test_dense_q_validation(rng):
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w, wq, scale = _quantized(rng, 8, 4)
    with pytest.raises(ValueError, match="real activations"):
        gemm.dense_q(x.astype(jnp.complex64), wq, scale)
    with pytest.raises(ValueError, match="activation"):
        gemm.dense_q(x, wq, scale, activation="tanh")
    with pytest.raises(ValueError, match="int8"):
        ops.matmul_q(x, wq.astype(jnp.int32), scale)
    with pytest.raises(ValueError, match="scale"):
        ops.matmul_q(x, wq, scale[:, :2])


# ----------------------------------------------------------------------
# dense_q VJP: the dequantized composition differentiates
# ----------------------------------------------------------------------

def test_dense_q_vjp_matches_unfused_composition(rng):
    x = jnp.asarray(rng.normal(size=(12, 24)), jnp.float32)
    w, wq, scale = _quantized(rng, 24, 16)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    def quant_loss(x_, s_, b_):
        return jnp.sum(gemm.dense_q(x_, wq, s_, b_, activation="gelu",
                                    policy=_PI) ** 2)

    def ref_loss(x_, s_, b_):
        w_ = wq.astype(jnp.float32) * s_
        return jnp.sum(jax.nn.gelu(x_ @ w_ + b_) ** 2)

    grads = jax.grad(quant_loss, argnums=(0, 1, 2))(x, scale, b)
    refs = jax.grad(ref_loss, argnums=(0, 1, 2))(x, scale, b)
    for g, r, name in zip(grads, refs, ("x", "scale", "b")):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=0,
            atol=1e-4 * max(float(jnp.max(jnp.abs(r))), 1.0), err_msg=name)


def test_dense_q_weight_cotangent_is_symbolic_zero(rng):
    """The int8 weight is a frozen buffer: jax hands back the float0
    symbolic zero for it rather than densifying a garbage gradient."""
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w, wq, scale = _quantized(rng, 8, 4)
    out, vjp = jax.vjp(lambda q_, s_: gemm.dense_q(x, q_, s_, policy=_XLA),
                       wq, scale)
    d_wq, d_scale = vjp(jnp.ones_like(out))
    assert d_wq.dtype == jax.dtypes.float0 and d_wq.shape == wq.shape
    assert d_scale.shape == scale.shape


# ----------------------------------------------------------------------
# fingerprint / cache-key separation + back-compat
# ----------------------------------------------------------------------

def test_kernel_fingerprint_folds_quant():
    assert Policy(backend="pallas", interpret=True).kernel_fingerprint \
        == "pallas_interpret"                      # historical spelling
    assert Policy(backend="pallas", interpret=True,
                  quant="int8").kernel_fingerprint == "pallas_interpret_int8"
    assert Policy(quant="int8").kernel_fingerprint == "xla_int8"
    p = Policy(backend="pallas", interpret=True, autotune="cached",
               quant="int8")
    assert Policy.parse(p.fingerprint()) == p


def test_preexisting_cache_keys_still_serve(tmp_path):
    """The acceptance contract: a tuning.json written before the quant
    field existed must keep serving under a quant='off' policy — and
    must NOT be served to the int8 population."""
    legacy_key = "matmul|64x48x32|float32|pallas_interpret"
    cache = TC.TuningCache(path=str(tmp_path / "tuning.json"), fingerprint="f")
    cache.put(legacy_key, {"bm": 8, "bn": 128, "bk": 128})
    pol = Policy(backend="pallas", interpret=True, autotune="cached")
    # the policy-era key spelling is byte-identical to the legacy one
    assert TC.matmul_key(64, 48, 32, "float32", pol) == legacy_key
    assert cache.get_matmul(64, 48, 32, "float32", pol) \
        == blocking.BlockConfig(8, 128, 128)
    # int8 population is disjoint: same shape, no crosstalk either way
    qpol = pol.replace(quant="int8")
    assert cache.get_matmul(64, 48, 32, "float32", qpol) is None
    assert cache.get_matmul_q(64, 48, 32, "float32", qpol) is None
    cache.put_matmul_q(64, 48, 32, "float32", qpol,
                       blocking.BlockConfig(16, 128, 128))
    assert cache.get_matmul(64, 48, 32, "float32", pol) \
        == blocking.BlockConfig(8, 128, 128)


def test_matmul_q_key_normalises_policy_quant():
    """Explicit ops.matmul_q under a quant='off' policy and dense_q
    under quant='int8' must share one entry population."""
    off = Policy(backend="pallas", interpret=True, autotune="cached")
    on = off.replace(quant="int8")
    assert TC.matmul_q_key(8, 8, 8, "float32", off) \
        == TC.matmul_q_key(8, 8, 8, "float32", on)
    assert TC.matmul_q_key(8, 8, 8, "float32", on).startswith("matmul_q|")


def test_matmul_q_served_from_cache(rng, tmp_path, monkeypatch):
    monkeypatch.setenv(TC.CACHE_ENV_VAR, str(tmp_path / "t.json"))
    TC.reset_cache()
    try:
        pol = Policy(backend="pallas", interpret=True, autotune="cached",
                     quant="int8")
        x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
        w, wq, scale = _quantized(rng, 32, 16)
        cache = TC.get_cache()
        cache.put_matmul_q(16, 16, 32, "float32", pol,
                           blocking.BlockConfig(8, 128, 128))
        hits = cache.hits
        y = ops.matmul_q(x, wq, scale, policy=pol)
        assert cache.hits == hits + 1
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(kref.matmul_q_ref(x, wq, scale)),
            rtol=1e-5, atol=1e-5)
    finally:
        TC.reset_cache()


# ----------------------------------------------------------------------
# registry error paths (regression-pins PR 4's contract for the new op)
# ----------------------------------------------------------------------

def test_matmul_q_registered_with_standard_backends():
    assert "matmul_q" in registry.registered_ops()
    assert registry.registered_backends("matmul_q") == \
        ("naive", "pallas", "xla")


def test_unknown_backend_and_epilogue_list_options(rng):
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w, wq, scale = _quantized(rng, 8, 4)
    with pytest.raises(ValueError) as e:
        ops.matmul_q(x, wq, scale, policy=Policy(backend="cuda"))
    assert "pallas" in str(e.value) and "xla" in str(e.value)
    with pytest.raises(ValueError, match="bias_silu"):
        ops.matmul_q(x, wq, scale, epilogue="bias_tanh")
    with pytest.raises(ValueError, match="registered ops"):
        registry.get_impl("matmul_q8", "xla")


def test_unknown_quant_mode_rejected_everywhere():
    with pytest.raises(ValueError, match="off"):
        Policy(quant="int4")
    with pytest.raises(ValueError, match="quant"):
        Policy.parse("backend=pallas,quant=fp8")
    with pytest.raises(ValueError, match="unknown policy field"):
        Policy.parse("quantize=int8")
    with pytest.raises(ValueError, match="quant mode"):
        AT.tune_matmul(8, 8, 8, quant="int4", policy=_PI)


# ----------------------------------------------------------------------
# param-tree quantization + serving engine integration
# ----------------------------------------------------------------------

def test_quantize_params_walker_targets_dense_only():
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qp = M.quantize_params(params)
    flat = jax.tree_util.tree_flatten_with_path(qp)[0]
    paths = {"/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path): leaf for path, leaf in flat}
    # embeddings stay float (gather path + tied lm_head)
    assert jnp.issubdtype(paths["embed/w"].dtype, jnp.floating)
    # dense layers are int8 + per-(layer,)channel scales
    int8 = {p for p, l in paths.items() if l.dtype == jnp.int8}
    assert int8 and all(p.endswith("w_q") for p in int8)
    scales = {p for p in paths if p.endswith("w_scale")}
    assert len(scales) == len(int8)
    # scanned stacks: the scale keeps the leading layer dim
    stacked = [paths[p] for p in int8 if paths[p].ndim == 3]
    if cfg.scan_layers:
        assert stacked


def test_quantize_params_excludes_router_and_expert_banks():
    cfg = get_config("mixtral-8x22b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qp = M.quantize_params(params)
    flat = jax.tree_util.tree_flatten_with_path(qp)[0]
    for path, leaf in flat:
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                     for x in path)
        if "router" in p or "embed" in p:
            assert leaf.dtype != jnp.int8, p
    # quantized forward still runs (MoE banks stay float, dense goes q)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "labels": jnp.zeros((1, 8), jnp.int32)}
    logits, _ = M.forward(cfg, qp, batch)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))


def test_engine_quantizes_at_construction_and_stays_token_exact():
    """policy.quant='int8' quantizes ONCE at engine construction; the
    continuous-batching decode must be token-exact vs a whole-prompt
    prefill over the same quantized params (same oracle as
    test_serving, on the quantized function)."""
    from repro.serving import ServingEngine
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (13,)).astype(np.int32)

    eng = ServingEngine(cfg, params, max_slots=2, max_len=64,
                        policy=Policy(quant="int8"))
    int8_leaves = [l for l in jax.tree.leaves(eng.params)
                   if l.dtype == jnp.int8]
    assert int8_leaves, "engine did not quantize its params"
    req = eng.submit(prompt, 5)
    eng.run()

    qp = M.quantize_params(params)
    L_ = len(prompt)
    cache = M.init_cache(cfg, 1, 64)
    logits, cache = M.prefill(cfg, qp, {"tokens": jnp.asarray(prompt[None])},
                              cache)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab]))]
    for i in range(4):
        logits, cache = M.decode_step(
            cfg, qp, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(L_ + i), cache)
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert toks == list(req.generated)


# ----------------------------------------------------------------------
# tuner + warm_start coverage
# ----------------------------------------------------------------------

def test_warm_start_maps_entries_to_matmul_q(tmp_path):
    """Under an int8 policy warm_start covers the shapes the quantized
    model ACTUALLY runs: dense layers as matmul_q, but a tied lm_head
    stays a plain matmul (the embedding is excluded from quantization,
    so embed_attend keeps routing through gemm.matmul)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    assert cfg.tie_embeddings
    pol = Policy(backend="pallas", interpret=True, autotune="cached",
                 quant="int8")
    cache = TC.TuningCache(path=str(tmp_path / "t.json"), fingerprint="f")
    rep = AT.warm_start(cfg, 1, 8, policy=pol, cache=cache, autotune=False)
    assert rep["misses"] and not rep["hits"]
    by_op = {}
    for e in rep["misses"]:
        by_op.setdefault(e[0], []).append(e)
    # attention shapes ride along un-quantized (int8 is a weight-side
    # policy; the flash ops stream activations only)
    assert set(by_op) == {"matmul_q", "matmul", "flash"}
    # the only plain entry is the tied-embedding logits GEMM
    assert [(m, n) for (_, m, n, k, ep) in by_op["matmul"]] \
        == [(8, cfg.padded_vocab)]
    assert rep["backend"].endswith("_int8")
    for (op, m, n, k, ep) in rep["misses"]:
        if op == "flash":
            cache.put_flash(m, n, k, cfg.dtype, pol,
                            blocking.FlashBlockConfig(128, 128))
            continue
        put = cache.put_matmul_q if op == "matmul_q" else cache.put_matmul
        put(m, n, k, cfg.dtype, pol, blocking.BlockConfig(8, 128, 128),
            epilogue=ep)
    rep2 = AT.warm_start(cfg, 1, 8, policy=pol, cache=cache, autotune=False)
    assert not rep2["misses"] and len(rep2["hits"]) == len(rep["misses"])


def test_tune_matmul_quant_sweeps_quantized_op(tmp_path):
    cache = TC.TuningCache(path=str(tmp_path / "t.json"), fingerprint="f")
    pol = Policy(backend="pallas", interpret=True, quant="int8")
    res = AT.tune_matmul(16, 16, 16, "float32", policy=pol, cache=cache,
                         iters=1, max_candidates=2, save=False)
    assert res.op == "matmul_q"
    assert res.key.startswith("matmul_q|16x16x16|float32|")
    assert cache.get_matmul_q(16, 16, 16, "float32", pol) == res.best
    # quant="off" against the same int8 policy tunes the PLAIN kernel
    # under the int8-tagged fingerprint (dense_q backward GEMMs)
    res2 = AT.tune_matmul(16, 16, 16, "float32", policy=pol, quant="off",
                          cache=cache, iters=1, max_candidates=2, save=False)
    assert res2.op == "matmul" and res2.key.startswith("matmul|")
    assert "_int8" in res2.key


# ----------------------------------------------------------------------
# modeled HBM-byte accounting (assertable without a TPU)
# ----------------------------------------------------------------------

def test_quant_traffic_model_reports_weight_side_saving():
    m, n, k, itemsize = 256, 1024, 1024, 4
    cfg = blocking.choose_block_config(m, n, k, itemsize)
    full = blocking.hbm_traffic_bytes(m, n, k, cfg, itemsize)
    quant = blocking.quant_traffic_bytes(m, n, k, cfg, itemsize)
    assert quant < full
    # the delta is exactly the weight stream shrinking 4x minus scales
    n_m = -(-m // cfg.bm)
    assert full - quant == k * n * (itemsize - 1) * n_m - n * 4 * n_m
    s = analysis.quant_gemm_savings(m, n, k, itemsize)
    assert 0.0 < s["saved_frac"] < 1.0
    assert s["weight_bytes_quant"] * itemsize == s["weight_bytes_full"]
    # decode shapes (tiny m) are weight-bound: bigger fraction saved
    decode = analysis.quant_gemm_savings(8, n, k, itemsize)
    assert decode["saved_frac"] > s["saved_frac"]
    # whole-MLP view vs the REAL fused-gated baseline: decode-shaped
    # MLPs win big; small activation-dominated shapes can go net
    # negative because the quantized gated path pays the A stream twice
    # (no int8 dual-GEMM kernel) — the model reports the honest trade.
    decode_layer = analysis.dense_q_layer_savings(8, 4096, 14336, 2)
    assert decode_layer["saved_frac"] > 0.4
    small_layer = analysis.dense_q_layer_savings(256, 128, 512, 2)
    assert -1.0 < small_layer["saved_frac"] < decode_layer["saved_frac"]
