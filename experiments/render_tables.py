"""Render EXPERIMENTS.md tables from dry-run / perf artifacts.

    python experiments/render_tables.py dryrun    # §Dry-run + §Roofline
    python experiments/render_tables.py perf      # §Perf iteration log
"""

import glob
import json
import os
import sys


def fmt_b(x):
    if x >= 2**40:
        return f"{x/2**40:.2f}T"
    if x >= 2**30:
        return f"{x/2**30:.1f}G"
    return f"{x/2**20:.0f}M"


def dryrun_tables():
    rows = {}
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        name = os.path.basename(path)[:-5]
        arch, shape, mesh = name.split("__")
        with open(path) as f:
            rows[(arch, shape, mesh)] = json.load(f)

    print("### Compile status (every arch x shape x mesh)\n")
    print("| arch | shape | 16x16 | 2x16x16 | bytes/dev (args+temp) |")
    print("|---|---|---|---|---|")
    archs = sorted({k[0] for k in rows})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in archs:
        for s in shapes:
            r1 = rows.get((a, s, "singlepod"))
            r2 = rows.get((a, s, "multipod"))
            if r1 is None:
                continue
            if r1.get("skipped"):
                print(f"| {a} | {s} | SKIP | SKIP | — |")
                continue
            ma = r1["memory_analysis"]
            tot = (ma.get("argument_size_in_bytes") or 0) + \
                  (ma.get("temp_size_in_bytes") or 0)
            ok2 = "OK" if (r2 and not r2.get("skipped")) else "?"
            print(f"| {a} | {s} | OK ({r1['compile_seconds']:.0f}s) "
                  f"| {ok2} | {fmt_b(tot)} |")

    print("\n### Roofline terms (single-pod 16x16, per device, seconds)\n")
    print("| arch | shape | t_comp | t_mem | t_coll | bound | "
          "MODEL/HLO flops | mfu* |")
    print("|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = rows.get((a, s, "singlepod"))
            if r is None or r.get("skipped"):
                continue
            print(f"| {a} | {s} | {r['t_compute']:.3f} | {r['t_memory']:.3f} "
                  f"| {r['t_collective']:.3f} | {r['bound']} "
                  f"| {r['useful_ratio']:.3f} | {r['mfu_roofline']:.4f} |")

    print("\n### Collective mix (single-pod; ICI GiB per device per step)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter "
          "| all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = rows.get((a, s, "singlepod"))
            if r is None or r.get("skipped"):
                continue
            c = r["collectives"]

            def g(op):
                return (c.get(op, {}).get("ici_bytes", 0.0)) / 2**30
            print(f"| {a} | {s} | {g('all-gather'):.1f} | {g('all-reduce'):.1f} "
                  f"| {g('reduce-scatter'):.1f} | {g('all-to-all'):.1f} "
                  f"| {g('collective-permute'):.1f} |")


def perf_tables():
    for cell in ("qwen1.5-32b__train_4k", "mixtral-8x22b__train_4k",
                 "mamba2-2.7b__prefill_32k"):
        paths = sorted(glob.glob(f"experiments/perf/{cell}__it*.json"))
        if not paths:
            continue
        print(f"\n#### {cell}\n")
        print("| iteration | t_comp | t_mem | t_coll | bound | mfu* | "
              "substitutions |")
        print("|---|---|---|---|---|---|---|")
        for p in paths:
            with open(p) as f:
                r = json.load(f)
            subs = "; ".join(r.get("substitutions", [])) or "—"
            print(f"| {r['label']} | {r['t_compute']:.3f} | {r['t_memory']:.3f}"
                  f" | {r['t_collective']:.3f} | {r['bound']} "
                  f"| {r['mfu_roofline']:.4f} | {subs} |")


if __name__ == "__main__":
    if sys.argv[1] == "dryrun":
        dryrun_tables()
    else:
        perf_tables()
