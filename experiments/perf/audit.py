import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Charge audit for one hillclimb iteration: top HBM charges outside
the kernel-substituted tags + collective breakdown.

    python experiments/perf/audit.py ARCH SHAPE [key=val ...]
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import lower_cell
from repro.roofline import hlo as H


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    overrides = {}
    for kv in sys.argv[3:]:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        overrides[k] = v
    compiled, rj = lower_cell(arch, shape, overrides=overrides or None,
                              verbose=False)
    costs = H.analyze(compiled.as_text(), 256)
    print(f"total hbm/dev: {costs.hbm_bytes/2**40:.2f} TiB   "
          f"tagged: { {k: f'{v/2**40:.2f}TiB' for k, v in costs.tagged_bytes.items()} }")
    print(f"ici/dev: {costs.ici_bytes/2**30:.1f} GiB")
    for op, d in sorted(costs.collective_summary().items()):
        print(f"  {op:22s} n={d['count']:6d} ici={d['ici_bytes']/2**30:9.1f}GiB")
    big = sorted(costs.collectives, key=lambda c: -c.ici_bytes * c.count)[:8]
    for c in big:
        print(f"    {c.op:20s} n={c.count:5d} res={c.bytes_result/2**20:8.1f}MiB "
              f"grp={c.group_size} {c.where[:44]}")
    print("top charges:")
    for b, desc in costs.top_charges(18):
        print(f"  {b/2**30:9.1f}GiB {desc[:110]}")


if __name__ == "__main__":
    main()
