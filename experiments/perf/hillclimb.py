import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

For one (arch x shape) cell, lower+compile a sequence of named
iterations (config overrides), re-derive the roofline terms per
iteration, optionally apply the Pallas-kernel substitution model, and
dump JSON per iteration.

Kernel-substitution model (applies to tm only, flops unchanged —
conservative): instructions inside jax.named_scope("flashsite") /
("ssdsite") are the attention / SSD chunk interiors. On the TPU target
these regions run as the Pallas kernels in kernels/ (flash_attention is
implemented + interpret-validated; the SSD analogue follows the Mamba-2
kernel structure), whose intermediates stay in VMEM. Substituted HBM
traffic = kernel I/O only:

  flash: fwd = q+k+v+o bytes;    train total = 4.5x fwd
         (fwd + remat re-fwd + bwd reading qkv,o,do writing dq,dk,dv)
  ssd:   fwd = 3 x (B*L*d_inner) * itemsize;  train total = 4.5x fwd

    PYTHONPATH=src python experiments/perf/hillclimb.py CELL
"""

import dataclasses
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro.configs import get_config
from repro.configs.base import get_shape
from repro.core import hw
from repro.launch.dryrun import lower_cell
from repro.roofline import hlo as H

CHIP = hw.TPU_V5E


def flash_io_bytes(cfg, cell, n_devices, *, attn_shards: int) -> float:
    """Per-device flash-kernel I/O bytes for the whole step.
    attn_shards = how many ways the attention tensors are sharded
    (dp x head-shards; 16*8=128 for qwen1.5's 8x2 factoring)."""
    b, t = cell.global_batch, cell.seq_len
    dh = cfg.resolved_head_dim
    itm = 2  # bf16 kernel I/O
    if cell.kind == "decode":
        tq, layers_mult = 1, 1.0
    elif cell.kind == "prefill":
        tq, layers_mult = t, 1.0
    else:
        tq, layers_mult = t, 4.5
    qo = 2 * b * tq * cfg.n_heads * dh * itm
    kv = 2 * b * t * cfg.n_kv_heads * dh * itm
    per_layer = (qo + kv) * layers_mult
    n_attn_layers = cfg.n_layers if cfg.family != "hybrid" \
        else cfg.n_layers // cfg.attn_every
    if cfg.family == "encdec":
        n_attn_layers = cfg.n_layers * 2 + cfg.n_enc_layers
    return per_layer * n_attn_layers / max(attn_shards, 1)


def ssd_io_bytes(cfg, cell, n_devices) -> float:
    b, t = cell.global_batch, cell.seq_len
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    itm = 2
    mult = 4.5 if cell.kind == "train" else 1.0
    if cell.kind == "decode":
        t = 1
    per_layer = 3 * b * t * d_inner * itm * mult
    return per_layer * cfg.n_layers / n_devices


def run_iteration(arch, shape, label, overrides=None, subs=(),
                  attn_shards=16, multi_pod=False):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = get_shape(shape)
    compiled, rj = lower_cell(arch, shape, overrides=overrides,
                              multi_pod=multi_pod, verbose=False)
    n_dev = 512 if multi_pod else 256
    costs = H.analyze(compiled.as_text(), n_dev)

    hbm = costs.hbm_bytes
    note = []
    for tag in subs:
        removed = costs.tagged_bytes.get(tag, 0.0)
        if tag == "flashsite":
            added = flash_io_bytes(cfg, cell, n_dev,
                                   attn_shards=attn_shards)
        else:
            added = ssd_io_bytes(cfg, cell, n_dev)
        hbm = hbm - removed + added
        note.append(f"{tag}: -{removed/2**30:.1f}GiB +{added/2**30:.2f}GiB")

    t_c = costs.flops / CHIP.peak_flops_bf16
    t_m = hbm / CHIP.hbm_bw
    t_coll = costs.ici_bytes / CHIP.ici_link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    bound = max(terms, key=terms.get)
    mf = rj["model_flops_total"]
    mfu = (mf / n_dev / CHIP.peak_flops_bf16) / max(max(terms.values()), 1e-30)

    out = {
        "cell": f"{arch}x{shape}", "label": label,
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_coll,
        "bound": bound, "mfu_roofline": mfu,
        "hbm_per_dev": hbm, "ici_per_dev": costs.ici_bytes,
        "flops_per_dev": costs.flops,
        "collectives": costs.collective_summary(),
        "substitutions": note,
        "compile_s": rj["compile_seconds"],
    }
    fn = f"experiments/perf/{arch}__{shape}__{label}.json"
    with open(fn, "w") as f:
        json.dump(out, f, indent=2, default=str)
    print(f"[{label:28s}] tc={t_c:8.3f}s tm={t_m:8.3f}s tcoll={t_coll:8.3f}s"
          f" bound={bound:10s} mfu*={mfu:.4f} {'; '.join(note)}")
    return out


def main():
    cell = sys.argv[1] if len(sys.argv) > 1 else "qwen15"
    if cell == "qwen15":
        a, s = "qwen1.5-32b", "train_4k"
        # final iteration ladder (earlier passes recorded in §Perf)
        run_iteration(a, s, "it0_replicate_baseline",
                      {"constrain_mode": "replicate"})
        run_iteration(a, s, "it1_free_head_dims")
        run_iteration(a, s, "it2_seqshard_vs_free",
                      {"shard_attn_seq": True})
        run_iteration(a, s, "it3_bf16_attn_io", {"attn_f32_io": False})
        run_iteration(a, s, "it4_flash_kernel", {"attn_f32_io": False},
                      subs=("flashsite",), attn_shards=16 * 8)
    elif cell == "mixtral":
        a, s = "mixtral-8x22b", "train_4k"
        run_iteration(a, s, "it0_baseline")   # with constraint-fix defaults
        run_iteration(a, s, "it1_bf16_attn_io", {"attn_f32_io": False})
        run_iteration(a, s, "it2_flash_kernel", {"attn_f32_io": False},
                      subs=("flashsite",), attn_shards=256)
        from repro.configs.base import MoEConfig
        cfg0 = get_config(a)
        moe_g128 = dataclasses.replace(cfg0.moe, group_size=128)
        run_iteration(a, s, "it3_moe_group128",
                      {"attn_f32_io": False, "moe": moe_g128},
                      subs=("flashsite",), attn_shards=256)
        run_iteration(a, s, "it4_remat_dots",
                      {"attn_f32_io": False, "remat": "dots"},
                      subs=("flashsite",), attn_shards=256)
        # it5: bf16 combine einsum (code change) + best-so-far
        run_iteration(a, s, "it5_bf16_combine_remat",
                      {"remat": "dots"},
                      subs=("flashsite",), attn_shards=256)
    elif cell == "mamba2":
        a, s = "mamba2-2.7b", "prefill_32k"
        run_iteration(a, s, "it0_baseline")   # with constraint-fix defaults
        from repro.configs.base import SSMConfig
        cfg0 = get_config(a)
        ssm128 = dataclasses.replace(cfg0.ssm, chunk=128)
        run_iteration(a, s, "it1_chunk128", {"ssm": ssm128})
        run_iteration(a, s, "it2_ssd_kernel", subs=("ssdsite",))
        ssm512 = dataclasses.replace(cfg0.ssm, chunk=512)
        run_iteration(a, s, "it3_chunk512_kernel", {"ssm": ssm512},
                      subs=("ssdsite",))
        # it4: split B/C/dt projection (now the code default) — removes
        # the per-layer broadcast of stranded state channels
        run_iteration(a, s, "it4_split_bc_proj")
        run_iteration(a, s, "it5_split_plus_kernel", subs=("ssdsite",))
    else:
        raise SystemExit(f"unknown cell {cell}")


if __name__ == "__main__":
    main()
