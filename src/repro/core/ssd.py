"""The SSD chokepoint: ambient-Policy routing + custom VJP for the
chunked Mamba-2 scan.

`models/ssm.py::mamba_apply` sends its prefill/train SSD scan here —
the SSM analogue of `core.gemm.dense` and `models.attention.attention`:
one call site, a typed `Policy` deciding which registered kernel runs,
and a `custom_vjp` that differentiates the *unfused* jnp composition
(`kernels.ssd.ssd_chunked`) so the fused Pallas forward trains without
a handwritten backward kernel; cotangent math follows the same f32
state discipline as the forward. The policy rides the nondiff slot, so
an identical policy never retraces and the backward runs under the
same policy object as the forward (tests/test_policy.py discipline).

f64 inputs reroute to the xla backend (no MXU path), mirroring the
attention and GEMM chokepoints.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import policy as _policy
from repro.core.policy import Policy
from repro.kernels import ops as kops
from repro.kernels.ssd import ssd_chunked


def _route_dtype(pol: Policy, dtype) -> Policy:
    if jnp.dtype(dtype) == jnp.float64 and pol.backend != "xla":
        return pol.replace(backend="xla")
    return pol


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_fused(x, a, b, c, s0, chunk, pol):
    return kops.ssd(x, a, b, c, chunk, init_state=s0, policy=pol)


def _ssd_fused_fwd(x, a, b, c, s0, chunk, pol):
    out = _ssd_fused(x, a, b, c, s0, chunk, pol)
    return out, (x, a, b, c, s0)


def _ssd_fused_bwd(chunk, pol, res, ct):
    # Differentiate the unfused composition — the same function every
    # registered backend computes — exactly as the gated-GEMM and
    # attention chokepoints do. The cotangents are pure jnp (GEMM-shaped
    # einsums + the scan transpose), so nothing here needs a policy.
    del pol
    x, a, b, c, s0 = res
    _, vjp = jax.vjp(
        lambda x_, a_, b_, c_, s_: ssd_chunked(
            x_, a_, b_, c_, chunk, init_state=s_),
        x, a, b, c, s0)
    return vjp(ct)


_ssd_fused.defvjp(_ssd_fused_fwd, _ssd_fused_bwd)


def ssd(
    x: jnp.ndarray,            # (B, L, H, P) — dt-scaled inputs
    a: jnp.ndarray,            # (B, L, H)    — dt*A log decays
    b: jnp.ndarray,            # (B, L, G, N)
    c: jnp.ndarray,            # (B, L, G, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
    *,
    policy: Optional[Policy] = None,
):
    """Policy-routed, differentiable SSD scan. Returns
    ``(y (B, L, H, P) in x.dtype, final_state (B, H, P, N) f32)``.

    Explicit `policy=` beats the ambient default (`Policy.scope()` /
    `set_default_policy`). A missing `init_state` becomes a zeros array
    before the custom_vjp so every differentiable argument is a real
    array (no Optional in the VJP signature) — its cotangent is simply
    discarded by callers that passed None.
    """
    pol = _route_dtype(_policy.resolve(policy, None), x.dtype)
    bsz, _, h, p = x.shape
    n = b.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    return _ssd_fused(x, a, b, c, init_state, chunk, pol)
