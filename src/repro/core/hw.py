"""Hardware model for the roofline / blocking analysis.

The container is CPU-only; TPU v5e is the *target*. All sizing decisions
(the paper's shared-memory-budget argument redone for VMEM) and all
roofline terms are computed against this model.

Numbers fixed by the task spec: 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    # Peak matmul throughput per chip, FLOP/s, by dtype.
    peak_flops_bf16: float
    peak_flops_f32: float
    # HBM bandwidth, bytes/s.
    hbm_bw: float
    hbm_bytes: int
    # VMEM (scratchpad) per core — the paper's "shared memory" analogue.
    vmem_bytes: int
    # ICI: per-link bandwidth (bytes/s, one direction) and links per chip
    # on a 2D torus (v5e: 4 neighbours × ~50 GB/s).
    ici_link_bw: float
    ici_links: int
    # MXU native tile (systolic array edge).
    mxu_dim: int
    # Minimum sublane×lane tile per dtype ((8,128) f32, (16,128) bf16, ...)
    lane: int = 128

    def sublane(self, itemsize: int) -> int:
        return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)

    def peak_flops(self, dtype_bytes: int) -> float:
        # f32 matmul on v5e-class MXUs runs as 3-pass bf16 (~1/3 rate);
        # f64 would be software-emulated (~1/10 of f32) — Fermi's 1/2-rate
        # DP has no native analogue on v5e (recorded in DESIGN.md §2).
        if dtype_bytes <= 2:
            return self.peak_flops_bf16
        if dtype_bytes == 4:
            return self.peak_flops_f32
        return self.peak_flops_f32 / 10.0


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=197e12 / 3.0,
    hbm_bw=819e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    ici_link_bw=50e9,
    ici_links=4,
    mxu_dim=128,
)

# The paper's own accelerators, used by the modeled Table-2 reproduction.
TESLA_C2050 = ChipSpec(
    name="tesla-c2050",
    peak_flops_bf16=1.03e12,     # no bf16 in 2010; use SP rate
    peak_flops_f32=1.03e12,
    hbm_bw=144e9,
    hbm_bytes=3 * 1024**3,
    vmem_bytes=48 * 1024,        # shared memory per SM
    ici_link_bw=8e9,             # PCIe 2.0 x16
    ici_links=1,
    mxu_dim=32,
)

TESLA_C1060 = ChipSpec(
    name="tesla-c1060",
    peak_flops_bf16=0.622e12,
    peak_flops_f32=0.622e12,
    hbm_bw=102e9,
    hbm_bytes=4 * 1024**3,
    vmem_bytes=16 * 1024,
    ici_link_bw=4e9,
    ici_links=1,
    mxu_dim=8,
)

DEFAULT_CHIP = TPU_V5E

#: Name -> spec registry (core.policy parses `chip=` policy fields
#: against this, so REPRO_POLICY can select any modeled chip).
CHIPS = {c.name: c for c in (TPU_V5E, TESLA_C2050, TESLA_C1060)}


def fingerprint(chip: ChipSpec | None = None) -> str:
    """Hardware identity string keying the tuning cache (repro.tuning).

    Tile timings only transfer between identical stacks, so the key
    combines the modeled chip, the physical device actually executing
    (platform + kind — interpret-mode timings on CPU must never be
    served to a real TPU), and the jax version (Mosaic codegen changes
    shift optima). Cache entries recorded under a different fingerprint
    are ignored and the static chooser in core.blocking is used instead.
    """
    import jax  # local: keep this module importable without jax

    chip = chip or DEFAULT_CHIP
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "unknown").replace(" ", "-")
    return f"{chip.name}|{dev.platform}|{kind}|jax-{jax.__version__}"
