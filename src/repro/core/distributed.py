"""Multi-accelerator GEMM — the paper's Tesla S2050 section, TPU-native.

The paper notes the block decomposition that feeds shared memory also
splits a GEMM across 4 GPUs, *if* the matrices are large enough to
amortise transfer. On TPU the analogue is mesh-sharded GEMM under
`shard_map`, and 'large enough' becomes a roofline statement
(core.intensity) about ICI bytes vs MXU flops.

Three schedules, increasing in sophistication:

  column_parallel    W sharded on N; no comm in fwd (comm in bwd).
  row_parallel       W sharded on K; one reduce-scatter (or all-reduce).
  ring_matmul        W sharded on K and *cycled* around the ring with
                     collective_permute while each device multiplies the
                     K-block it currently holds — the compute hides the
                     permute (async start/done in HLO). This is the
                     beyond-paper overlap schedule measured in §Perf.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import gemm as _gemm


def column_parallel(x, w, *, axis: str, backend: str | None = None):
    """Y[..., N/p] = X @ W[:, N/p]; inputs replicated, output sharded."""
    return _gemm.matmul(x, w, backend=backend)


def row_parallel(x, w, *, axis: str, backend: str | None = None,
                 scatter: bool = True):
    """X sharded on K (last dim), W sharded on K (first dim).

    scatter=True emits reduce-scatter (output row-sharded), else
    all-reduce (output replicated).
    """
    part = _gemm.matmul(x, w, backend=backend)
    if scatter:
        return jax.lax.psum_scatter(part, axis, scatter_dimension=part.ndim - 1,
                                    tiled=True)
    return jax.lax.psum(part, axis)


def ring_matmul(x, w, *, axis: str, backend: str | None = None):
    """Ring-overlapped Y = X @ W.

    Per-device state: x_local (M_local, K) — full K; w_local (K/p, N) —
    this device's K-block of W. Step t: multiply the K-block we hold,
    pass it to the next ring neighbour. P-1 permutes hide behind P local
    GEMMs of shape (M_local, K/p, N).
    """
    # jax >= 0.5 has lax.axis_size; the psum-of-1 idiom is the portable
    # spelling (constant-folded to a static int for named axes).
    p = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis))
    idx = jax.lax.axis_index(axis)
    kb = w.shape[0]          # local K block
    n = w.shape[1]
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(t, carry):
        acc, w_t = carry
        # K-block currently held = the one originally owned by (idx - t).
        owner = (idx - t) % p
        x_blk = jax.lax.dynamic_slice_in_dim(x, owner * kb, kb, axis=x.ndim - 1)
        acc = acc + _gemm.matmul(x_blk, w_t, backend=backend)
        w_t = jax.lax.ppermute(w_t, axis, perm)
        return acc, w_t

    acc0 = jnp.zeros(x.shape[:-1] + (n,), dtype=x.dtype)
    if hasattr(jax.lax, "pvary"):  # jax >= 0.5 varying-manual-axes type
        acc0 = jax.lax.pvary(acc0, (axis,))  # match the loop body's vma
    acc, _ = jax.lax.fori_loop(0, p, body, (acc0, w))
    return acc


def sharded_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    mesh: Mesh,
    *,
    axis: str = "model",
    schedule: str = "ring",
    backend: str | None = None,
) -> jnp.ndarray:
    """Top-level multi-device GEMM (the S2050 reproduction entry point).

    A (M, K) is sharded on M over `axis` for ring/column, on K for row;
    B (K, N) is sharded to match the schedule. Returns the full product.
    """
    if schedule == "ring":
        fn = shard_map(
            functools.partial(ring_matmul, axis=axis, backend=backend),
            mesh=mesh,
            in_specs=(P(axis, None), P(axis, None)),
            out_specs=P(axis, None),
        )
        return fn(a, b)
    if schedule == "column":
        fn = shard_map(
            functools.partial(column_parallel, axis=axis, backend=backend),
            mesh=mesh,
            in_specs=(P(axis, None), P(None, None)),
            out_specs=P(axis, None),
        )
        return fn(a, b)
    if schedule == "row":
        fn = shard_map(
            functools.partial(row_parallel, axis=axis, backend=backend,
                              scatter=False),
            mesh=mesh,
            in_specs=(P(None, axis), P(axis, None)),
            out_specs=P(None, None),
        )
        return fn(a, b)
    raise ValueError(f"unknown schedule {schedule!r}")


def comm_model_bytes(m: int, n: int, k: int, p: int, itemsize: int,
                     schedule: str) -> int:
    """ICI bytes per device for each schedule — the 'matrices must be
    very large' claim quantified (used by bench_distributed_gemm)."""
    if schedule == "column":
        return 0
    if schedule == "row":
        return 2 * m * n * itemsize * (p - 1) // p      # all-reduce
    if schedule == "ring":
        return k * n * itemsize * (p - 1) // p          # W blocks cycled
    raise ValueError(schedule)
