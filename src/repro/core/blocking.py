"""Tile-size selection for the blocked GEMM — the paper's shared-memory
sizing argument ("2 * 16 * 16 * 8 B = 4 KB <= 48 KB") redone for the TPU
memory hierarchy.

On the GPU the block size trades shared-memory footprint against
occupancy; on TPU it trades VMEM footprint against DMA pipeline depth
and MXU alignment. The constraints implemented here:

  * every tile dim is a multiple of the MXU edge (128) where possible,
    and at least the (sublane, lane) minimum for the dtype;
  * A-tile + B-tile (double-buffered) + f32 accumulator must fit a VMEM
    budget (default: half of VMEM, leaving room for Mosaic);
  * maximise arithmetic intensity  AI = 2*bm*bn*bk / (bm*bk + bk*bn + bm*bn)
    which is what makes the kernel compute-bound (paper claim C2).

Also provides the HBM-traffic model used by the Fig.-8 reproduction:
tiled GEMM reads A ceil(N/bn) times and B ceil(M/bm) times, which is the
paper's reuse argument in byte form.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, itemsize: int, double_buffer: bool = True,
                   n_rhs: int = 1) -> int:
        """Working set of the tiled kernel. n_rhs > 1 models the fused
        dual-GEMM variants (kernels.matmul.gated_matmul_tiled): one A
        tile staged against n_rhs B operands, one accumulator each."""
        mult = 2 if double_buffer else 1
        tiles = (self.bm * self.bk
                 + n_rhs * self.bk * self.bn) * itemsize * mult
        acc = n_rhs * self.bm * self.bn * 4  # f32 accumulator scratch
        return tiles + acc

    def arithmetic_intensity(self, itemsize: int, n_rhs: int = 1) -> float:
        flops = 2.0 * n_rhs * self.bm * self.bn * self.bk
        bytes_moved = (self.bm * self.bk
                       + n_rhs * self.bk * self.bn) * itemsize
        return flops / bytes_moved


@dataclasses.dataclass(frozen=True)
class FlashBlockConfig:
    """Tile sizes for the flash-attention kernel: (bq, d) query tiles
    resident in VMEM, (bk, d) key/value tiles streamed through."""
    bq: int
    bk: int

    def vmem_bytes(self, d: int, itemsize: int,
                   double_buffer: bool = True) -> int:
        mult = 2 if double_buffer else 1
        tiles = (self.bq * d + 2 * self.bk * d) * itemsize * mult
        # f32 scratch: output accumulator + running max + denominator.
        acc = (self.bq * d + 2 * self.bq * 128) * 4
        return tiles + acc


def choose_flash_config(
    tq: int,
    tk: int,
    d: int,
    itemsize: int = 2,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> FlashBlockConfig:
    """Default (bq, bk) for flash attention — the kernel's historical
    constants, clamped to the sequence lengths. The autotuner
    (repro.tuning) sweeps alternatives and caches per-shape winners."""
    return FlashBlockConfig(bq=min(256, tq), bk=min(512, tk))


def choose_decode_config(
    tk: int,
    d: int,
    itemsize: int = 2,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> FlashBlockConfig:
    """Default K/V tile for the q_len=1 decode kernel. The query tile is
    a single row by construction, so the only knob is how much of the
    cache streams per grid step; 512 keeps the DMA pipeline deep while
    the prefix skip (pos < k_start) bounds wasted blocks to one."""
    return FlashBlockConfig(bq=1, bk=min(512, tk))


@dataclasses.dataclass(frozen=True)
class SSDBlockConfig:
    """Tile sizes for the SSD intra-chunk kernel: `q` is the execution
    chunk along time (any divisor of the model chunk computes the same
    function — SSD chunking is exact), `bp` tiles the head dim (each
    p-tile recomputes the (q, q) decay/score matrices)."""
    q: int
    bp: int

    def vmem_bytes(self, n: int, itemsize: int,
                   double_buffer: bool = True) -> int:
        mult = 2 if double_buffer else 1
        # streamed per grid cell: x (q, bp), a (q,), b/c (q, n)
        tiles = (self.q * self.bp + self.q + 2 * self.q * n) * itemsize * mult
        # f32 scratch: decay mask + score matrix (q, q) each, y (q, bp),
        # chunk state (n, bp)
        acc = (2 * self.q * self.q + self.q * self.bp + n * self.bp) * 4
        return tiles + acc


def choose_ssd_config(
    chunk: int,
    p: int,
    n: int,
    itemsize: int = 4,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
) -> SSDBlockConfig:
    """Default (q, bp) for the SSD kernel: run at the model's configured
    chunk with the full head dim, halving the time tile while the
    working set (dominated by the two (q, q) f32 matrices) exceeds the
    VMEM budget. The autotuner (tuning.tune_ssd) sweeps alternatives."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    q = chunk
    cfg = SSDBlockConfig(q=q, bp=p)
    while cfg.vmem_bytes(n, itemsize) > budget and q % 2 == 0 and q > 8:
        q //= 2
        cfg = SSDBlockConfig(q=q, bp=p)
    return cfg


def ssd_traffic_bytes(
    l: int, h: int, p: int, n: int, cfg: SSDBlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM<->VMEM by the Pallas SSD composition for one
    (batch, layer): the kernel streams x/a and the head-broadcast b/c
    once per head-tile column (`ceil(p/bp)` — b/c re-stream when the
    head dim is tiled), writes the chunk-diagonal y and the per-chunk
    states in f32, and the tiny rank-N inter-chunk pass reads the states
    + y_diag and writes y. The (q, q) decay mask and CB score matrices
    are VMEM-resident and never exist in HBM — the term this model
    conspicuously lacks, mirroring flash_traffic_bytes."""
    nc = math.ceil(l / cfg.q)
    n_p = math.ceil(p / cfg.bp)
    x_bytes = l * h * p * itemsize
    a_bytes = l * h * itemsize * n_p
    bc_bytes = 2 * l * h * n * itemsize * n_p
    y_diag = l * h * p * 4                      # kernel out, f32
    states = nc * h * n * p * 4                 # kernel out, f32
    # inter-chunk jnp pass: read states + y_diag + c, write y
    inter = states + y_diag + l * h * n * itemsize + l * h * p * itemsize
    return x_bytes + a_bytes + bc_bytes + y_diag + states + inter


def ssd_unfused_traffic_bytes(
    l: int, h: int, p: int, n: int, chunk: int, itemsize: int
) -> int:
    """The XLA lowering of the chunked composition (kernels.ssd
    ssd_chunked): the per-chunk (Q, Q) f32 decay mask is written + read
    and the CB score matrix is written + read twice (once masked for
    y_diag, once raw) — four quadratic f32 trips per (chunk, head),
    `4 * Q*Q * 4` bytes, exactly the flash_unfused_traffic_bytes
    pattern along the time axis — plus the linear operand streams, the
    f32 decay vectors and the per-chunk state round trip."""
    nc = math.ceil(l / chunk)
    operands = (l * h * p + l * h + 2 * l * h * n) * itemsize
    s_bytes = nc * h * 4 * chunk * chunk * 4    # ldec + cb round trips
    decays = 3 * l * h * 4                      # a_cum, decay_to_end, ...
    states = 2 * nc * h * n * p * 4             # written, re-read by scan
    y_bytes = 2 * l * h * p * 4 + l * h * p * itemsize  # y_diag+y_off+y
    return operands + s_bytes + decays + states + y_bytes


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _round_down_pow2_mult(x: int, m: int) -> int:
    """Largest multiple of m that is <= x (at least m)."""
    return max(m, (x // m) * m)


def choose_block_config(
    m: int,
    n: int,
    k: int,
    itemsize: int = 2,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    n_rhs: int = 1,
) -> BlockConfig:
    """Pick (bm, bn, bk) for an (m, k) x (k, n) GEMM.

    Strategy: start from MXU-aligned 512x512x512-ish tiles, clamp to the
    problem, then shrink the largest dim until the double-buffered
    working set fits the VMEM budget. bk is kept >= 512 when possible so
    the k-grid is short (fewer accumulator passes), mirroring the
    paper's 'one long k loop inside the block' structure.

    n_rhs=2 sizes tiles for the fused dual-GEMM (gated) kernel, whose
    working set carries two B tiles and two accumulators per A tile.
    """
    budget = int(chip.vmem_bytes * vmem_fraction)
    lane = chip.lane
    sub = chip.sublane(itemsize)

    bm = min(_round_up(m, sub), 512)
    bn = min(_round_up(n, lane), 512)
    bk = min(_round_up(k, lane), 2048)
    bm = _round_down_pow2_mult(bm, sub)
    bn = _round_down_pow2_mult(bn, lane)
    bk = _round_down_pow2_mult(bk, lane)

    cfg = BlockConfig(bm, bn, bk)
    while cfg.vmem_bytes(itemsize, n_rhs=n_rhs) > budget:
        # Shrink the dim that frees the most bytes while hurting AI least:
        # prefer shrinking bk first below 512, then the larger of bm/bn.
        if cfg.bk > 512:
            cfg = BlockConfig(cfg.bm, cfg.bn, _round_down_pow2_mult(cfg.bk // 2, lane))
        elif cfg.bm >= cfg.bn and cfg.bm > sub:
            cfg = BlockConfig(_round_down_pow2_mult(cfg.bm // 2, sub), cfg.bn, cfg.bk)
        elif cfg.bn > lane:
            cfg = BlockConfig(cfg.bm, _round_down_pow2_mult(cfg.bn // 2, lane), cfg.bk)
        elif cfg.bk > lane:
            cfg = BlockConfig(cfg.bm, cfg.bn, _round_down_pow2_mult(cfg.bk // 2, lane))
        else:
            break  # minimum tile; give up shrinking
    return cfg


def hbm_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM->VMEM by the tiled kernel (the Fig.-8 model).

    A is streamed once per N-block column, B once per M-block row, C is
    written once. This is exactly the paper's reuse argument: blocking
    divides global-memory traffic by the block edge.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = k * n * itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + c_bytes


def gated_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM->VMEM by the fused dual-GEMM (gated) kernel.

    One A stream feeds BOTH weight operands (A read once per N-block
    column, exactly as in the single-GEMM model), each of the two B
    operands is read once per M-block row, and only the final gated
    product is written — the two (m, n) intermediates of the unfused
    composition never touch HBM.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = 2 * k * n * itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + c_bytes


def quant_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int,
    w_itemsize: int = 1, scale_itemsize: int = 4,
) -> int:
    """Bytes moved HBM->VMEM by the int8-weight tiled kernel
    (kernels.matmul.matmul_q_tiled).

    Same reuse structure as hbm_traffic_bytes, but the B operand is
    stored at `w_itemsize` (1 for int8) and a (1, N) per-channel scale
    row rides along once per M-block row — the whole point of the
    quantized path is that the weight stream shrinks itemsize/w_itemsize
    x while A, C and the arithmetic stay full precision.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = k * n * w_itemsize * n_m
    s_bytes = n * scale_itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + s_bytes + c_bytes


def naive_traffic_bytes(m: int, n: int, k: int, itemsize: int) -> int:
    """Traffic model for the hierarchy-blind kernel (paper Listing 3).

    Each output element streams a full row of A and column of B with no
    cross-thread reuse: A read n times, B read m times.
    """
    return (m * k * n + k * n * m + m * n) * itemsize


def flash_traffic_bytes(
    tq: int, tk: int, d: int, cfg: FlashBlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM<->VMEM by the fused flash-attention forward, per
    (batch x head) slice — multiply by B*H for a layer.

    The q grid axis is outer and the kv axis inner, and the Q block index
    is constant across consecutive kv steps, so Mosaic keeps each Q tile
    resident: Q and O move once. K and V re-stream once per Q block row.
    The S and P matrices never exist in HBM — that is the whole point,
    and the term this model conspicuously lacks."""
    n_q = math.ceil(tq / cfg.bq)
    q_bytes = tq * d * itemsize
    kv_bytes = 2 * tk * d * itemsize * n_q
    o_bytes = tq * d * itemsize
    return q_bytes + kv_bytes + o_bytes


def flash_unfused_traffic_bytes(tq: int, tk: int, d: int,
                                itemsize: int) -> int:
    """The materialised-softmax baseline: one pass writes S = QK^T, a
    second normalises it to P, a third contracts with V. Operands move
    once (XLA fuses the row softmax into one read-modify-write), but the
    (tq, tk) score matrix makes four f32 HBM trips: S written + read,
    P written + read."""
    qkv_bytes = (tq + 2 * tk) * d * itemsize
    s_bytes = 4 * tq * tk * 4
    o_bytes = tq * d * itemsize
    return qkv_bytes + s_bytes + o_bytes


def decode_traffic_bytes(pos: int, tk: int, d: int, cfg: FlashBlockConfig,
                         itemsize: int) -> int:
    """Fused decode-step traffic per (batch x head): the single query row
    and output row bracket a K/V stream that covers only the valid cache
    prefix — the kernel's `k_start <= pos` skip means blocks past the
    write head are never DMA'd, so a depth-4096 cache at pos=127 moves
    ceil(128/bk)*bk rows, not 4096."""
    n_blocks = math.ceil((pos + 1) / cfg.bk)
    kv_bytes = 2 * n_blocks * cfg.bk * d * itemsize
    return kv_bytes + 2 * d * itemsize


def decode_unfused_traffic_bytes(pos: int, tk: int, d: int,
                                 itemsize: int) -> int:
    """The masked-dense decode baseline (chunked/XLA over the whole
    cache buffer): padding cannot be skipped because the mask is data,
    so all tk cache rows stream, plus the (1, tk) score row's f32 round
    trips. `pos` is accepted for signature symmetry — the baseline's
    traffic does not depend on it, which is exactly the problem."""
    del pos
    kv_bytes = 2 * tk * d * itemsize
    s_bytes = 4 * tk * 4
    return kv_bytes + s_bytes + 2 * d * itemsize


def flash_bwd_traffic_bytes(
    tq: int, tk: int, d: int, cfg: FlashBlockConfig, itemsize: int
) -> int:
    """Recompute-style flash backward, per (batch x head): two sweeps,
    neither of which ever reads or writes the (tq, tk) matrices.

    Sweep 1 (dK/dV, kv-outer grid): K/V move once, the q-side streams
    (q, do + the f32 lse/delta rows) re-read per kv block row, dK/dV
    written once in f32. Sweep 2 (dQ, q-outer grid): mirror image.
    delta = rowsum(do * o) is a pre-pass in XLA: o and do read once more.
    """
    n_q = math.ceil(tq / cfg.bq)
    n_k = math.ceil(tk / cfg.bk)
    rows = 2 * tq * 4                          # lse + delta, f32
    q_stream = 2 * tq * d * itemsize + rows    # q + do + rows
    sweep1 = 2 * tk * d * itemsize + n_k * q_stream + 2 * tk * d * 4
    sweep2 = q_stream + n_q * 2 * tk * d * itemsize + tq * d * 4
    delta_pass = 2 * tq * d * itemsize + tq * 4
    return sweep1 + sweep2 + delta_pass


def flash_bwd_stored_traffic_bytes(tq: int, tk: int, d: int,
                                   itemsize: int) -> int:
    """Stored-S attention backward: the classic formulation keeps the
    (tq, tk) probability matrix from the forward and replays it. P is
    read twice (dV and dS), dS is written then re-read for dQ/dK — four
    f32 trips of the quadratic matrix, dwarfing the linear operands."""
    operands = (3 * tq + 2 * tk) * d * itemsize   # q, do, o, k, v
    s_bytes = 4 * tq * tk * 4
    outs = (tq + 2 * tk) * d * 4                  # dq, dk, dv in f32
    return operands + s_bytes + outs + 2 * tq * 4


def gemm_time_model(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    cfg: BlockConfig | None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> dict:
    """Roofline time estimate for one GEMM on `chip`.

    cfg=None means the naive traffic model. Returns both terms plus the
    bound classification — the machinery behind the modeled Table-2
    reproduction.
    """
    flops = 2.0 * m * n * k
    if cfg is None:
        traffic = naive_traffic_bytes(m, n, k, itemsize)
    else:
        traffic = hbm_traffic_bytes(m, n, k, cfg, itemsize)
    t_compute = flops / chip.peak_flops(itemsize)
    t_memory = traffic / chip.hbm_bw
    return {
        "flops": flops,
        "bytes": traffic,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_total": max(t_compute, t_memory),
        "bound": "compute" if t_compute >= t_memory else "memory",
        "arithmetic_intensity": flops / traffic,
    }
