"""Tile-size selection for the blocked GEMM — the paper's shared-memory
sizing argument ("2 * 16 * 16 * 8 B = 4 KB <= 48 KB") redone for the TPU
memory hierarchy.

On the GPU the block size trades shared-memory footprint against
occupancy; on TPU it trades VMEM footprint against DMA pipeline depth
and MXU alignment. The constraints implemented here:

  * every tile dim is a multiple of the MXU edge (128) where possible,
    and at least the (sublane, lane) minimum for the dtype;
  * A-tile + B-tile (double-buffered) + f32 accumulator must fit a VMEM
    budget (default: half of VMEM, leaving room for Mosaic);
  * maximise arithmetic intensity  AI = 2*bm*bn*bk / (bm*bk + bk*bn + bm*bn)
    which is what makes the kernel compute-bound (paper claim C2).

Also provides the HBM-traffic model used by the Fig.-8 reproduction:
tiled GEMM reads A ceil(N/bn) times and B ceil(M/bm) times, which is the
paper's reuse argument in byte form.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, itemsize: int, double_buffer: bool = True,
                   n_rhs: int = 1) -> int:
        """Working set of the tiled kernel. n_rhs > 1 models the fused
        dual-GEMM variants (kernels.matmul.gated_matmul_tiled): one A
        tile staged against n_rhs B operands, one accumulator each."""
        mult = 2 if double_buffer else 1
        tiles = (self.bm * self.bk
                 + n_rhs * self.bk * self.bn) * itemsize * mult
        acc = n_rhs * self.bm * self.bn * 4  # f32 accumulator scratch
        return tiles + acc

    def arithmetic_intensity(self, itemsize: int, n_rhs: int = 1) -> float:
        flops = 2.0 * n_rhs * self.bm * self.bn * self.bk
        bytes_moved = (self.bm * self.bk
                       + n_rhs * self.bk * self.bn) * itemsize
        return flops / bytes_moved


@dataclasses.dataclass(frozen=True)
class FlashBlockConfig:
    """Tile sizes for the flash-attention kernel: (bq, d) query tiles
    resident in VMEM, (bk, d) key/value tiles streamed through."""
    bq: int
    bk: int

    def vmem_bytes(self, d: int, itemsize: int,
                   double_buffer: bool = True) -> int:
        mult = 2 if double_buffer else 1
        tiles = (self.bq * d + 2 * self.bk * d) * itemsize * mult
        # f32 scratch: output accumulator + running max + denominator.
        acc = (self.bq * d + 2 * self.bq * 128) * 4
        return tiles + acc


def choose_flash_config(
    tq: int,
    tk: int,
    d: int,
    itemsize: int = 2,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> FlashBlockConfig:
    """Default (bq, bk) for flash attention — the kernel's historical
    constants, clamped to the sequence lengths. The autotuner
    (repro.tuning) sweeps alternatives and caches per-shape winners."""
    return FlashBlockConfig(bq=min(256, tq), bk=min(512, tk))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _round_down_pow2_mult(x: int, m: int) -> int:
    """Largest multiple of m that is <= x (at least m)."""
    return max(m, (x // m) * m)


def choose_block_config(
    m: int,
    n: int,
    k: int,
    itemsize: int = 2,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    n_rhs: int = 1,
) -> BlockConfig:
    """Pick (bm, bn, bk) for an (m, k) x (k, n) GEMM.

    Strategy: start from MXU-aligned 512x512x512-ish tiles, clamp to the
    problem, then shrink the largest dim until the double-buffered
    working set fits the VMEM budget. bk is kept >= 512 when possible so
    the k-grid is short (fewer accumulator passes), mirroring the
    paper's 'one long k loop inside the block' structure.

    n_rhs=2 sizes tiles for the fused dual-GEMM (gated) kernel, whose
    working set carries two B tiles and two accumulators per A tile.
    """
    budget = int(chip.vmem_bytes * vmem_fraction)
    lane = chip.lane
    sub = chip.sublane(itemsize)

    bm = min(_round_up(m, sub), 512)
    bn = min(_round_up(n, lane), 512)
    bk = min(_round_up(k, lane), 2048)
    bm = _round_down_pow2_mult(bm, sub)
    bn = _round_down_pow2_mult(bn, lane)
    bk = _round_down_pow2_mult(bk, lane)

    cfg = BlockConfig(bm, bn, bk)
    while cfg.vmem_bytes(itemsize, n_rhs=n_rhs) > budget:
        # Shrink the dim that frees the most bytes while hurting AI least:
        # prefer shrinking bk first below 512, then the larger of bm/bn.
        if cfg.bk > 512:
            cfg = BlockConfig(cfg.bm, cfg.bn, _round_down_pow2_mult(cfg.bk // 2, lane))
        elif cfg.bm >= cfg.bn and cfg.bm > sub:
            cfg = BlockConfig(_round_down_pow2_mult(cfg.bm // 2, sub), cfg.bn, cfg.bk)
        elif cfg.bn > lane:
            cfg = BlockConfig(cfg.bm, _round_down_pow2_mult(cfg.bn // 2, lane), cfg.bk)
        elif cfg.bk > lane:
            cfg = BlockConfig(cfg.bm, cfg.bn, _round_down_pow2_mult(cfg.bk // 2, lane))
        else:
            break  # minimum tile; give up shrinking
    return cfg


def hbm_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM->VMEM by the tiled kernel (the Fig.-8 model).

    A is streamed once per N-block column, B once per M-block row, C is
    written once. This is exactly the paper's reuse argument: blocking
    divides global-memory traffic by the block edge.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = k * n * itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + c_bytes


def gated_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int
) -> int:
    """Bytes moved HBM->VMEM by the fused dual-GEMM (gated) kernel.

    One A stream feeds BOTH weight operands (A read once per N-block
    column, exactly as in the single-GEMM model), each of the two B
    operands is read once per M-block row, and only the final gated
    product is written — the two (m, n) intermediates of the unfused
    composition never touch HBM.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = 2 * k * n * itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + c_bytes


def quant_traffic_bytes(
    m: int, n: int, k: int, cfg: BlockConfig, itemsize: int,
    w_itemsize: int = 1, scale_itemsize: int = 4,
) -> int:
    """Bytes moved HBM->VMEM by the int8-weight tiled kernel
    (kernels.matmul.matmul_q_tiled).

    Same reuse structure as hbm_traffic_bytes, but the B operand is
    stored at `w_itemsize` (1 for int8) and a (1, N) per-channel scale
    row rides along once per M-block row — the whole point of the
    quantized path is that the weight stream shrinks itemsize/w_itemsize
    x while A, C and the arithmetic stay full precision.
    """
    n_m = math.ceil(m / cfg.bm)
    n_n = math.ceil(n / cfg.bn)
    a_bytes = m * k * itemsize * n_n
    b_bytes = k * n * w_itemsize * n_m
    s_bytes = n * scale_itemsize * n_m
    c_bytes = m * n * itemsize
    return a_bytes + b_bytes + s_bytes + c_bytes


def naive_traffic_bytes(m: int, n: int, k: int, itemsize: int) -> int:
    """Traffic model for the hierarchy-blind kernel (paper Listing 3).

    Each output element streams a full row of A and column of B with no
    cross-thread reuse: A read n times, B read m times.
    """
    return (m * k * n + k * n * m + m * n) * itemsize


def gemm_time_model(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    cfg: BlockConfig | None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> dict:
    """Roofline time estimate for one GEMM on `chip`.

    cfg=None means the naive traffic model. Returns both terms plus the
    bound classification — the machinery behind the modeled Table-2
    reproduction.
    """
    flops = 2.0 * m * n * k
    if cfg is None:
        traffic = naive_traffic_bytes(m, n, k, itemsize)
    else:
        traffic = hbm_traffic_bytes(m, n, k, cfg, itemsize)
    t_compute = flops / chip.peak_flops(itemsize)
    t_memory = traffic / chip.hbm_bw
    return {
        "flops": flops,
        "bytes": traffic,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_total": max(t_compute, t_memory),
        "bound": "compute" if t_compute >= t_memory else "memory",
        "arithmetic_intensity": flops / traffic,
    }
