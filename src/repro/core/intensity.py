"""Arithmetic-intensity classification — paper claim C3 generalized.

The paper observes that matrix addition (AI ~ 1/12 flop/byte for f32)
gains nothing from the accelerator while GEMM (AI ~ n/6) gains 1000x.
This module turns that observation into a reusable classifier used by
the benchmarks and the roofline reporting.
"""

from __future__ import annotations

import dataclasses

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class OpProfile:
    name: str
    flops: float
    hbm_bytes: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)


def machine_balance(chip: hw.ChipSpec = hw.DEFAULT_CHIP, itemsize: int = 2) -> float:
    """FLOPs/byte the chip can sustain; ops below this are memory-bound."""
    return chip.peak_flops(itemsize) / chip.hbm_bw


def classify(profile: OpProfile, chip: hw.ChipSpec = hw.DEFAULT_CHIP,
             itemsize: int = 2) -> dict:
    balance = machine_balance(chip, itemsize)
    ai = profile.arithmetic_intensity
    t_compute = profile.flops / chip.peak_flops(itemsize)
    t_memory = profile.hbm_bytes / chip.hbm_bw
    return {
        "name": profile.name,
        "arithmetic_intensity": ai,
        "machine_balance": balance,
        "bound": "compute" if ai >= balance else "memory",
        "t_compute": t_compute,
        "t_memory": t_memory,
        "attainable_flops": min(chip.peak_flops(itemsize), ai * chip.hbm_bw),
        "roofline_fraction": min(1.0, ai / balance),
    }


def matmul_profile(m: int, n: int, k: int, itemsize: int) -> OpProfile:
    return OpProfile(
        name=f"matmul_{m}x{k}x{n}",
        flops=2.0 * m * n * k,
        hbm_bytes=float((m * k + k * n + m * n) * itemsize),
    )


def add_profile(m: int, n: int, itemsize: int) -> OpProfile:
    """C = A + B: one flop per element, three arrays of traffic (Fig. 9)."""
    return OpProfile(
        name=f"add_{m}x{n}",
        flops=float(m * n),
        hbm_bytes=float(3 * m * n * itemsize),
    )
