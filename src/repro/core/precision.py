"""Dtype policies — the paper's float / double / complex-float study
(Table 2) as a first-class framework concept.

TPU MXUs have no native f64 or complex path, so:

  * f64 GEMM is dispatched to the XLA backend (or interpret-mode Pallas
    in tests with x64 enabled); the roofline model charges it at the
    emulated rate (hw.ChipSpec.peak_flops).
  * complex64 GEMM is decomposed into REAL GEMMs. We implement both the
    textbook 4-multiply form and the 3-multiply (Gauss/Karatsuba) form

        re = A_re B_re - A_im B_im
        im = (A_re + A_im)(B_re + B_im) - A_re B_re - A_im B_im

    which trades one GEMM for three adds — a beyond-paper optimisation
    (25% fewer MXU flops) validated against jnp complex matmul.

The ladder also extends *downward*: per-channel symmetric int8 weight
quantization (`QuantSpec` + `quantize`/`dequantize`) stores W as one
byte per element plus one f32 scale per output channel, cutting the
weight-side HBM traffic 2-4x (the same bandwidth argument as the fused
SwiGLU kernel). Accumulation stays f32 — reduced-precision *storage*
with higher-precision *arithmetic*, the canonical accelerator trade.
The quantized GEMM itself lives in kernels.matmul.matmul_q_tiled and is
dispatched through core.gemm.dense_q.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How a logical dtype maps onto kernel execution."""
    name: str
    compute_dtype: jnp.dtype      # dtype fed to the MXU
    accum_dtype: jnp.dtype        # accumulator dtype
    out_dtype: jnp.dtype          # result dtype


POLICIES = {
    "bf16": PrecisionPolicy("bf16", jnp.bfloat16, jnp.float32, jnp.bfloat16),
    "f32": PrecisionPolicy("f32", jnp.float32, jnp.float32, jnp.float32),
    "bf16_f32out": PrecisionPolicy("bf16_f32out", jnp.bfloat16, jnp.float32, jnp.float32),
}


# ----------------------------------------------------------------------
# int8 weight quantization (the precision ladder's downward rung)
# ----------------------------------------------------------------------

#: Quantization modes a QuantSpec can describe. Policy.quant adds "off"
#: on top (no spec at all); the two tuples are pinned against each other
#: in tests/test_quant.py.
QUANT_MODES = ("int8",)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How a weight tensor is quantized.

    mode: storage format ("int8" — symmetric, zero-point-free).
    axis: the CONTRACTION axis reduced when computing the per-channel
        amax. For a (K, N) dense weight the default -2 reduces over K,
        yielding one scale per output channel N; for a scanned stack's
        (L, K, N) weight the same axis yields per-(layer, channel)
        scales (L, 1, N) that scan slices alongside the int8 leaf.
    """
    mode: str = "int8"
    axis: int = -2

    def __post_init__(self):
        if self.mode not in QUANT_MODES:
            raise ValueError(
                f"unknown quantization mode {self.mode!r}; expected one "
                f"of {QUANT_MODES} (Policy.quant additionally accepts "
                "'off')")


def quantize_int8(w: jnp.ndarray, axis: int = -2):
    """Per-channel symmetric int8: ``(q, scale)`` with
    ``q = round(w / scale)`` clipped to [-127, 127] and
    ``scale = amax / 127`` reduced over the contraction `axis`
    (keepdims, so ``q * scale`` broadcasts back to w's shape).

    The symmetric grid never needs a zero point, and amax/127 means the
    extreme value is representable exactly — round-to-nearest bounds the
    element error by scale/2 (tests/test_quant.py pins this).
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax, 127.0) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize(w: jnp.ndarray, spec: QuantSpec):
    """Quantize `w` per `spec` -> (q, scale)."""
    if spec.mode == "int8":
        return quantize_int8(w, axis=spec.axis)
    raise ValueError(f"unknown quantization mode {spec.mode!r}; "
                     f"expected one of {QUANT_MODES}")


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the float weight: ``q * scale`` in scale's dtype."""
    return q.astype(scale.dtype) * scale


#: QuantSpec for KV-cache rows: the contraction axis of a K/V row is
#: head_dim (the last axis — q.k reduces over it), so the amax reduce
#: runs over -1 and yields one f32 scale per (position, kv-head).
KV_QUANT_SPEC = QuantSpec(mode="int8", axis=-1)


def quantize_kv(x: jnp.ndarray):
    """Quantize K/V rows for int8 KV pages: per-(position, head)
    symmetric int8 over the head_dim axis — the axis the decode dot
    contracts. Returns ``(q, scale)`` with the keepdims singleton
    squeezed off the scale (page pools store scales as their own
    (..., position, head) plane, not broadcast against head_dim)."""
    q, scale = quantize(x, KV_QUANT_SPEC)
    return q, jnp.squeeze(scale, axis=-1)


def quant_error_bound(scale: jnp.ndarray) -> jnp.ndarray:
    """Tight per-element reconstruction bound: |deq - w| <= scale / 2
    (round-to-nearest on the symmetric grid; no clipping error because
    scale = amax/127 puts the extremes exactly on the grid)."""
    return scale * 0.5


def complex_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    real_matmul: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    algorithm: str = "gauss3",
) -> jnp.ndarray:
    """Complex GEMM via real GEMMs (paper's complex-float column).

    `real_matmul` is any real-valued GEMM implementation (XLA, tiled
    Pallas, naive Pallas) — the decomposition is backend-agnostic so the
    whole Table-2 dtype matrix runs through the paper's kernel.
    """
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    if algorithm == "naive4":
        re = real_matmul(ar, br) - real_matmul(ai, bi)
        im = real_matmul(ar, bi) + real_matmul(ai, br)
    elif algorithm == "gauss3":
        t1 = real_matmul(ar, br)
        t2 = real_matmul(ai, bi)
        t3 = real_matmul(ar + ai, br + bi)
        re = t1 - t2
        im = t3 - t1 - t2
    else:
        raise ValueError(f"unknown complex algorithm {algorithm!r}")
    return (re + 1j * im).astype(_complex_of(a.dtype))


def _complex_of(dtype) -> jnp.dtype:
    return jnp.complex128 if jnp.dtype(dtype) == jnp.complex128 else jnp.complex64


def gemm_flops(m: int, n: int, k: int, dtype) -> float:
    """Useful-FLOP count per dtype (complex = 4x real in the naive form,
    3x with gauss3 — we charge the 4x 'mathematical' count so speedups
    from gauss3 show up as >1 efficiency, same convention as the paper's
    elementary-operation counting)."""
    base = 2.0 * m * n * k
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return 4.0 * base
    return base
