"""Dtype policies — the paper's float / double / complex-float study
(Table 2) as a first-class framework concept.

TPU MXUs have no native f64 or complex path, so:

  * f64 GEMM is dispatched to the XLA backend (or interpret-mode Pallas
    in tests with x64 enabled); the roofline model charges it at the
    emulated rate (hw.ChipSpec.peak_flops).
  * complex64 GEMM is decomposed into REAL GEMMs. We implement both the
    textbook 4-multiply form and the 3-multiply (Gauss/Karatsuba) form

        re = A_re B_re - A_im B_im
        im = (A_re + A_im)(B_re + B_im) - A_re B_re - A_im B_im

    which trades one GEMM for three adds — a beyond-paper optimisation
    (25% fewer MXU flops) validated against jnp complex matmul.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How a logical dtype maps onto kernel execution."""
    name: str
    compute_dtype: jnp.dtype      # dtype fed to the MXU
    accum_dtype: jnp.dtype        # accumulator dtype
    out_dtype: jnp.dtype          # result dtype


POLICIES = {
    "bf16": PrecisionPolicy("bf16", jnp.bfloat16, jnp.float32, jnp.bfloat16),
    "f32": PrecisionPolicy("f32", jnp.float32, jnp.float32, jnp.float32),
    "bf16_f32out": PrecisionPolicy("bf16_f32out", jnp.bfloat16, jnp.float32, jnp.float32),
}


def complex_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    real_matmul: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    algorithm: str = "gauss3",
) -> jnp.ndarray:
    """Complex GEMM via real GEMMs (paper's complex-float column).

    `real_matmul` is any real-valued GEMM implementation (XLA, tiled
    Pallas, naive Pallas) — the decomposition is backend-agnostic so the
    whole Table-2 dtype matrix runs through the paper's kernel.
    """
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    if algorithm == "naive4":
        re = real_matmul(ar, br) - real_matmul(ai, bi)
        im = real_matmul(ar, bi) + real_matmul(ai, br)
    elif algorithm == "gauss3":
        t1 = real_matmul(ar, br)
        t2 = real_matmul(ai, bi)
        t3 = real_matmul(ar + ai, br + bi)
        re = t1 - t2
        im = t3 - t1 - t2
    else:
        raise ValueError(f"unknown complex algorithm {algorithm!r}")
    return (re + 1j * im).astype(_complex_of(a.dtype))


def _complex_of(dtype) -> jnp.dtype:
    return jnp.complex128 if jnp.dtype(dtype) == jnp.complex128 else jnp.complex64


def gemm_flops(m: int, n: int, k: int, dtype) -> float:
    """Useful-FLOP count per dtype (complex = 4x real in the naive form,
    3x with gauss3 — we charge the 4x 'mathematical' count so speedups
    from gauss3 show up as >1 efficiency, same convention as the paper's
    elementary-operation counting)."""
    base = 2.0 * m * n * k
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return 4.0 * base
    return base
