"""The GEMM chokepoint.

Every dense contraction in the model zoo — QKV/O projections, FFN, MoE
expert GEMMs, logits, SSD chunk matmuls — routes through `matmul()` /
`dense()` / `gated_mlp()` here, so switching the ambient execution
Policy (core.policy) swaps the paper's tiled kernel in and out of the
*whole framework* (the reproduce-vs-optimise axis of EXPERIMENTS.md).
`Policy(autotune="cached")` additionally swaps the static tile chooser
for per-shape winners from the autotuner cache (repro.tuning; launchers
warm it via tuning.warm_start).

Responsibilities on top of kernels.ops:
  * batched / n-d shapes (leading dims folded into M);
  * complex64 decomposition into real GEMMs (core.precision, Table 2);
  * f64 routing (no MXU path — XLA or interpret only);
  * int8-weight GEMMs: `dense_q()` is the quantized twin of `dense()`
    (weights from core.precision.quantize_int8, the matmul_q kernel op,
    full epilogue lattice); its custom VJP differentiates the
    dequantized f32 composition — cotangents for x and scale, a
    symbolic zero for the int8 weight;
  * fused-epilogue eligibility: `dense(activation=..., residual=...)`
    and `gated_mlp()` run the fused Pallas flush only for real
    f32/bf16-class dtypes on the pallas backend (and only while
    policy.fuse_epilogues holds); f64/complex and the xla backend fall
    back to the same composition unfused;
  * custom VJPs so the Pallas backends train: the Policy rides the
    nondiff argument slot (it is frozen + hashable) and every cotangent
    GEMM — including those of the fused dense/gated paths — recurses
    through the same chokepoint with the SAME policy, so autotuned
    tiles serve backward too.

Execution selection: explicit `policy=` > deprecated string `backend=`
> the ambient default (core.policy.current_policy — scope() /
set_default_policy / $REPRO_POLICY). The pre-Policy entry points
`set_default_backend` / `use_backend` survive below as deprecation
shims over that ambient default.
"""

from __future__ import annotations

import contextlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as _pol
from repro.core import precision as _prec
from repro.core.policy import Policy
from repro.kernels import ops as _ops


# ----------------------------------------------------------------------
# deprecated string-backend shims (the ambient default is now a Policy)
# ----------------------------------------------------------------------

def set_default_backend(name: str) -> None:
    """Deprecated: set_default_policy(Policy.from_backend(name))."""
    _pol.warn_deprecated(
        "set_default_backend",
        "core.gemm.set_default_backend is deprecated; use "
        "repro.core.policy.set_default_policy(Policy.from_backend(name)). "
        "Note: the default is now process-wide (the old function was "
        "per-thread) — use Policy.from_backend(name).scope() for "
        "thread-local selection")
    _pol.set_default_policy(Policy.from_backend(name))


@contextlib.contextmanager
def use_backend(name: str):
    """Deprecated: Policy.from_backend(name).scope()."""
    _pol.warn_deprecated(
        "use_backend",
        "core.gemm.use_backend is deprecated; use "
        "Policy.from_backend(name).scope()")
    with Policy.from_backend(name).scope():
        yield


# ----------------------------------------------------------------------
# 2D chokepoint + custom VJP (policy is the nondiff argument)
# ----------------------------------------------------------------------

def _route_dtype(dtype, policy: Policy) -> Policy:
    """f64 has no MXU path: compiled (non-interpret) kernel backends
    fall back to XLA emulation; the interpreter runs f64 fine."""
    if (jnp.dtype(dtype) == jnp.float64 and policy.backend != "xla"
            and not policy.resolved_interpret):
        return policy.replace(backend="xla")
    return policy


def _matmul_2d(a, b, policy: Policy, out_dtype):
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        if policy.backend == "xla":
            return _ops.matmul(a, b, policy=policy, out_dtype=out_dtype)
        real = lambda x, y: _ops.matmul(x, y, policy=policy)
        return _prec.complex_matmul(a, b, real, algorithm="gauss3")
    policy = _route_dtype(a.dtype, policy)
    return _ops.matmul(a, b, policy=policy, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul_vjp(a, b, policy, out_dtype):
    return _matmul_2d(a, b, policy, out_dtype)


def _matmul_fwd(a, b, policy, out_dtype):
    return _matmul_2d(a, b, policy, out_dtype), (a, b)


def _matmul_bwd(policy, out_dtype, res, g):
    a, b = res
    g = g.astype(a.dtype)
    da = _matmul_2d(g, b.T, policy, a.dtype)
    db = _matmul_2d(a.T, g, policy, b.dtype)
    return da, db


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None,
           policy: Policy | None = None,
           backend: str | None = None) -> jnp.ndarray:
    """A @ B for a: (..., M, K), b: (K, N) or (..., K, N) matching."""
    pol = _pol.resolve(policy, backend)
    out_dtype = out_dtype or pol.resolved_out_dtype(a.dtype)
    if a.ndim == b.ndim == 2:
        return _matmul_vjp(a, b, pol, out_dtype)
    if b.ndim == 2:
        lead = a.shape[:-1]
        out = _matmul_vjp(a.reshape(-1, a.shape[-1]), b, pol, out_dtype)
        return out.reshape(*lead, b.shape[-1])
    # batched-batched: vmap the 2D chokepoint over leading dims.
    assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
    lead = a.shape[:-2]
    af = a.reshape((-1,) + a.shape[-2:])
    bf = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(lambda x, y: _matmul_vjp(x, y, pol, out_dtype))(af, bf)
    return out.reshape(lead + out.shape[-2:])


# ----------------------------------------------------------------------
# Fused epilogues: dense(activation=, residual=) and gated_mlp()
# ----------------------------------------------------------------------

_ACTIVATIONS = {"gelu": jax.nn.gelu, "silu": jax.nn.silu}
_ACT_EPILOGUE = {"gelu": "bias_gelu", "silu": "bias_silu", None: "bias"}


def _fusible(dtype, policy: Policy) -> bool:
    """Fused epilogues run only where the tiled kernel itself runs: the
    pallas backend on a real non-f64 dtype, with the policy's
    fuse_epilogues toggle on. Everything else (xla, naive, f64 without
    an MXU path, complex decomposition, fuse_epilogues=False) composes
    the same function unfused through the plain chokepoint."""
    return (policy.backend == "pallas"
            and policy.fuse_epilogues
            and not jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating)
            and jnp.dtype(dtype) != jnp.float64)


def _dense_ep_2d(x, w, b, r, activation, policy, out_dtype):
    """y = act(x @ w + b) + r on 2D operands, fused where eligible.

    Fusion rule: (bias, activation) take the fused flush when present;
    a residual rides the fused flush only when it is the *sole*
    epilogue (the kernel lattice is bias*/act XOR residual)."""
    if not _fusible(x.dtype, policy):
        y = _matmul_2d(x, w, policy, out_dtype)
        if b is not None:
            y = y + b.astype(y.dtype)
        if activation is not None:
            y = _ACTIVATIONS[activation](y)
        if r is not None:
            y = y + r.astype(y.dtype)
        return y
    if b is not None or activation is not None:
        bias = b if b is not None else jnp.zeros((w.shape[-1],), x.dtype)
        y = _ops.matmul(x, w, policy=policy, out_dtype=out_dtype,
                        epilogue=_ACT_EPILOGUE[activation], bias=bias)
        if r is not None:
            y = y + r.astype(y.dtype)
        return y
    if r is not None:
        if r.shape == (x.shape[0], w.shape[-1]):
            return _ops.matmul(x, w, policy=policy, out_dtype=out_dtype,
                               epilogue="residual", residual=r)
        # broadcastable-but-not-(m, n) residual: add it unfused so the
        # xla and Pallas backends keep computing the same function
        y = _ops.matmul(x, w, policy=policy, out_dtype=out_dtype)
        return y + r.astype(y.dtype)
    return _ops.matmul(x, w, policy=policy, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _dense_ep_vjp(x, w, b, r, activation, policy, out_dtype):
    return _dense_ep_2d(x, w, b, r, activation, policy, out_dtype)


def _dense_ep_fwd(x, w, b, r, activation, policy, out_dtype):
    return _dense_ep_2d(x, w, b, r, activation, policy, out_dtype), \
        (x, w, b, r)


def _dense_ep_bwd(activation, policy, out_dtype, res, g):
    """Differentiate the unfused composition built on the matmul
    chokepoint: the recompute GEMM and both cotangent GEMMs all recurse
    through _matmul_vjp with the same policy, so the pallas/autotuned
    configurations serve them too."""
    x, w, b, r = res

    def ref(ops_):
        z = _matmul_vjp(ops_["x"], ops_["w"], policy, out_dtype)
        if "b" in ops_:
            z = z + ops_["b"].astype(z.dtype)
        if activation is not None:
            z = _ACTIVATIONS[activation](z)
        if "r" in ops_:
            z = z + ops_["r"].astype(z.dtype)
        return z

    prim = {"x": x, "w": w}
    if b is not None:
        prim["b"] = b
    if r is not None:
        prim["r"] = r
    out, vjp = jax.vjp(ref, prim)
    d = vjp(g.astype(out.dtype))[0]
    return d["x"], d["w"], d.get("b"), d.get("r")


_dense_ep_vjp.defvjp(_dense_ep_fwd, _dense_ep_bwd)


def _gated_2d(x, wg, wu, policy, out_dtype):
    if not _fusible(x.dtype, policy):
        g = _matmul_2d(x, wg, policy, out_dtype)
        u = _matmul_2d(x, wu, policy, out_dtype)
        return (jax.nn.silu(g) * u).astype(out_dtype)
    return _ops.gated_matmul(x, wg, wu, policy=policy, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _gated_vjp(x, wg, wu, policy, out_dtype):
    return _gated_2d(x, wg, wu, policy, out_dtype)


def _gated_fwd(x, wg, wu, policy, out_dtype):
    return _gated_2d(x, wg, wu, policy, out_dtype), (x, wg, wu)


def _gated_bwd(policy, out_dtype, res, g):
    x, wg, wu = res

    def ref(x_, wg_, wu_):
        gt = _matmul_vjp(x_, wg_, policy, out_dtype)
        up = _matmul_vjp(x_, wu_, policy, out_dtype)
        return jax.nn.silu(gt) * up

    out, vjp = jax.vjp(ref, x, wg, wu)
    return vjp(g.astype(out.dtype))


_gated_vjp.defvjp(_gated_fwd, _gated_bwd)


# ----------------------------------------------------------------------
# Quantized dense: int8 weights through the matmul_q op
# ----------------------------------------------------------------------

def _dense_q_2d(x, wq, scale, b, r, activation, policy, out_dtype):
    """y = act((x @ wq) * scale + b) + r on 2D operands. Same fusion
    rule as _dense_ep_2d — the quantized kernel carries the full
    epilogue lattice, so (bias, activation) ride the fused flush and a
    lone (m, n) residual does too; everything else composes unfused
    through the same matmul_q op (xla/naive backends, f64 reroute)."""
    pol = _route_dtype(x.dtype, policy)
    if not _fusible(x.dtype, pol):
        y = _ops.matmul_q(x, wq, scale, policy=pol, out_dtype=out_dtype)
        if b is not None:
            y = y + b.astype(y.dtype)
        if activation is not None:
            y = _ACTIVATIONS[activation](y)
        if r is not None:
            y = y + r.astype(y.dtype)
        return y
    if b is not None or activation is not None:
        bias = b if b is not None else jnp.zeros((wq.shape[-1],), x.dtype)
        y = _ops.matmul_q(x, wq, scale, policy=pol, out_dtype=out_dtype,
                          epilogue=_ACT_EPILOGUE[activation], bias=bias)
        if r is not None:
            y = y + r.astype(y.dtype)
        return y
    if r is not None:
        if r.shape == (x.shape[0], wq.shape[-1]):
            return _ops.matmul_q(x, wq, scale, policy=pol,
                                 out_dtype=out_dtype, epilogue="residual",
                                 residual=r)
        y = _ops.matmul_q(x, wq, scale, policy=pol, out_dtype=out_dtype)
        return y + r.astype(y.dtype)
    return _ops.matmul_q(x, wq, scale, policy=pol, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _dense_q_vjp(x, wq, scale, b, r, activation, policy, out_dtype):
    return _dense_q_2d(x, wq, scale, b, r, activation, policy, out_dtype)


def _dense_q_fwd(x, wq, scale, b, r, activation, policy, out_dtype):
    return _dense_q_2d(x, wq, scale, b, r, activation, policy, out_dtype), \
        (x, wq, scale, b, r)


def _dense_q_bwd(activation, policy, out_dtype, res, g):
    """Differentiate the dequantized f32 composition: the recompute and
    cotangent GEMMs recurse through _matmul_vjp with the same policy
    (autotuned tiles serve them), d_scale arrives via the dequant chain
    rule, and the int8 weight — an integer leaf — gets the symbolic
    float0 zero jax expects for non-differentiable dtypes."""
    x, wq, scale, b, r = res

    def ref(ops_):
        w = (wq.astype(jnp.float32)
             * ops_["scale"].reshape(1, -1)).astype(x.dtype)
        z = _matmul_vjp(ops_["x"], w, policy, out_dtype)
        if "b" in ops_:
            z = z + ops_["b"].astype(z.dtype)
        if activation is not None:
            z = _ACTIVATIONS[activation](z)
        if "r" in ops_:
            z = z + ops_["r"].astype(z.dtype)
        return z

    prim = {"x": x, "scale": scale}
    if b is not None:
        prim["b"] = b
    if r is not None:
        prim["r"] = r
    out, vjp = jax.vjp(ref, prim)
    d = vjp(g.astype(out.dtype))[0]
    d_wq = np.zeros(wq.shape, dtype=jax.dtypes.float0)
    return d["x"], d_wq, d["scale"], d.get("b"), d.get("r")


_dense_q_vjp.defvjp(_dense_q_fwd, _dense_q_bwd)


def dense_q(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
            b: jnp.ndarray | None = None, *, activation: str | None = None,
            residual: jnp.ndarray | None = None, out_dtype=None,
            policy: Policy | None = None,
            backend: str | None = None) -> jnp.ndarray:
    """y = act((x @ wq) * scale + b) + residual — `dense` with
    per-channel int8 weights (core.precision.quantize_int8: wq (K, N)
    int8, scale (1, N) f32) for x: (..., K). The pallas backend streams
    int8 weight tiles and dequantizes on the f32 accumulator in the
    kernel flush; activations stay f32/bf16 (complex is meaningless
    against an int8 grid and rejected; f64 activations reroute like
    `dense`). Differentiable in x, scale, b, residual — the int8 weight
    is a frozen buffer."""
    pol = _pol.resolve(policy, backend)
    out_dtype = out_dtype or pol.resolved_out_dtype(x.dtype)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError("dense_q needs real activations; complex inputs "
                         "have no int8 weight decomposition")
    if activation not in (None, *_ACTIVATIONS):
        raise ValueError(f"unknown activation {activation!r}; expected "
                         f"one of {(None, *_ACTIVATIONS)}")
    if x.ndim == 2:
        return _dense_q_vjp(x, wq, scale, b, residual, activation, pol,
                            out_dtype)
    xf, lead = _fold_leading(x)
    rf = residual.reshape(-1, residual.shape[-1]) \
        if residual is not None else None
    out = _dense_q_vjp(xf, wq, scale, b, rf, activation, pol, out_dtype)
    return out.reshape(*lead, wq.shape[-1])


def _fold_leading(x):
    return x.reshape(-1, x.shape[-1]), x.shape[:-1]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
          *, activation: str | None = None,
          residual: jnp.ndarray | None = None,
          out_dtype=None, policy: Policy | None = None,
          backend: str | None = None) -> jnp.ndarray:
    """y = act(x @ w + b) + residual for x: (..., K), w: (K, N) — the
    layer-level API. activation in {None, "gelu", "silu"}. residual
    should match the output shape (the fused flush requires it; a 2D
    broadcastable residual is added unfused instead). On the pallas
    backend bias/activation (and a lone full-shape residual) are
    applied inside the kernel's flush phase — see kernels.matmul
    EPILOGUES."""
    pol = _pol.resolve(policy, backend)
    out_dtype = out_dtype or pol.resolved_out_dtype(x.dtype)
    if b is None and activation is None and residual is None:
        return matmul(x, w, out_dtype=out_dtype, policy=pol)
    if activation not in (None, *_ACTIVATIONS):
        raise ValueError(f"unknown activation {activation!r}; expected "
                         f"one of {(None, *_ACTIVATIONS)}")
    if x.ndim == 2:
        return _dense_ep_vjp(x, w, b, residual, activation, pol, out_dtype)
    xf, lead = _fold_leading(x)
    rf = residual.reshape(-1, residual.shape[-1]) \
        if residual is not None else None
    out = _dense_ep_vjp(xf, w, b, rf, activation, pol, out_dtype)
    return out.reshape(*lead, w.shape[-1])


def gated_mlp(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
              *, out_dtype=None, policy: Policy | None = None,
              backend: str | None = None) -> jnp.ndarray:
    """silu(x @ w_gate) * (x @ w_up) — the SwiGLU hidden phase.

    x: (..., K); weights (K, F), or batched (..., K, F) with matching
    leading dims (MoE expert banks — vmapped over the 2D chokepoint).
    The pallas backend runs the dual-GEMM kernel: one A stream against
    both weight operands, no HBM intermediates."""
    pol = _pol.resolve(policy, backend)
    out_dtype = out_dtype or pol.resolved_out_dtype(x.dtype)
    assert w_gate.shape == w_up.shape, (w_gate.shape, w_up.shape)
    if w_gate.ndim == 2:
        if x.ndim == 2:
            return _gated_vjp(x, w_gate, w_up, pol, out_dtype)
        xf, lead = _fold_leading(x)
        out = _gated_vjp(xf, w_gate, w_up, pol, out_dtype)
        return out.reshape(*lead, w_gate.shape[-1])
    assert x.shape[:-2] == w_gate.shape[:-2], (x.shape, w_gate.shape)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    gf = w_gate.reshape((-1,) + w_gate.shape[-2:])
    uf = w_up.reshape((-1,) + w_up.shape[-2:])
    out = jax.vmap(
        lambda x_, g_, u_: _gated_vjp(x_, g_, u_, pol, out_dtype)
    )(xf, gf, uf)
    return out.reshape(lead + out.shape[-2:])
