"""The GEMM chokepoint.

Every dense contraction in the model zoo — QKV/O projections, FFN, MoE
expert GEMMs, logits, SSD chunk matmuls — routes through `matmul()` /
`dense()` here, so switching the global backend swaps the paper's tiled
kernel in and out of the *whole framework* (the reproduce-vs-optimise
axis of EXPERIMENTS.md). The "tuned" backend additionally swaps the
static tile chooser for per-shape winners from the autotuner cache
(repro.tuning; launchers warm it via tuning.warm_start).

Responsibilities on top of kernels.ops:
  * batched / n-d shapes (leading dims folded into M);
  * complex64 decomposition into real GEMMs (core.precision, Table 2);
  * f64 routing (no MXU path — XLA or interpret only);
  * a custom VJP so the Pallas backends train: both cotangent GEMMs
    recurse through the same chokepoint.
"""

from __future__ import annotations

import contextlib
import threading
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import precision as _prec
from repro.kernels import ops as _ops

_state = threading.local()


def _backend() -> str:
    return getattr(_state, "backend", "xla")


def set_default_backend(name: str) -> None:
    assert name in _ops.MATMUL_BACKENDS, name
    _state.backend = name


@contextlib.contextmanager
def use_backend(name: str):
    prev = _backend()
    set_default_backend(name)
    try:
        yield
    finally:
        set_default_backend(prev)


def _matmul_2d(a, b, backend, out_dtype):
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        if backend == "xla":
            return _ops.matmul(a, b, backend="xla", out_dtype=out_dtype)
        real = lambda x, y: _ops.matmul(x, y, backend=backend)
        return _prec.complex_matmul(a, b, real, algorithm="gauss3")
    if a.dtype == jnp.float64 and backend in ("pallas", "naive", "tuned"):
        # no MXU f64 path: compiled-TPU f64 falls back to XLA emulation.
        backend = "xla"
    return _ops.matmul(a, b, backend=backend, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul_vjp(a, b, backend, out_dtype):
    return _matmul_2d(a, b, backend, out_dtype)


def _matmul_fwd(a, b, backend, out_dtype):
    return _matmul_2d(a, b, backend, out_dtype), (a, b)


def _matmul_bwd(backend, out_dtype, res, g):
    a, b = res
    g = g.astype(a.dtype)
    da = _matmul_2d(g, b.T, backend, a.dtype)
    db = _matmul_2d(a.T, g, backend, b.dtype)
    return da, db


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None,
           backend: str | None = None) -> jnp.ndarray:
    """A @ B for a: (..., M, K), b: (K, N) or (..., K, N) matching."""
    backend = backend or _backend()
    out_dtype = out_dtype or a.dtype
    if a.ndim == b.ndim == 2:
        return _matmul_vjp(a, b, backend, out_dtype)
    if b.ndim == 2:
        lead = a.shape[:-1]
        out = _matmul_vjp(a.reshape(-1, a.shape[-1]), b, backend, out_dtype)
        return out.reshape(*lead, b.shape[-1])
    # batched-batched: vmap the 2D chokepoint over leading dims.
    assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
    lead = a.shape[:-2]
    af = a.reshape((-1,) + a.shape[-2:])
    bf = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(lambda x, y: _matmul_vjp(x, y, backend, out_dtype))(af, bf)
    return out.reshape(lead + out.shape[-2:])


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
          *, out_dtype=None, backend: str | None = None) -> jnp.ndarray:
    """y = x @ w (+ b) for x: (..., K), w: (K, N) — the layer-level API."""
    y = matmul(x, w, out_dtype=out_dtype, backend=backend)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y
