"""Execution policy — the one typed object that says HOW to run.

The paper's speedup is a function of execution strategy (hierarchy-aware
tiling vs. naive vs. host fallback, compiled vs. interpreted, static vs.
autotuned tiles). Before this module that strategy was smeared across
free-form strings ("tuned_interpret"), ad-hoc ``interpret=`` kwargs and
a module-global default in core.gemm. `Policy` collects every execution
knob into a frozen, hashable dataclass:

    backend         WHICH kernel family: "xla" | "pallas" | "naive"
                    (validated at dispatch against the kernel registry,
                    kernels.registry — not a hand-maintained tuple)
    interpret       run Pallas kernels in the interpreter (None = auto:
                    interpret everywhere except a real TPU)
    chip            the hardware model used for tile sizing
    autotune        "off" = static chooser; "cached" = serve tile
                    winners from the autotuner cache (repro.tuning)
    fuse_epilogues  allow bias/act/residual to ride the kernel flush
    out_dtype       default output dtype name (None = input dtype)
    quant           weight quantization: "off" | "int8" (per-channel
                    symmetric int8 storage, f32 accumulation — routes
                    dense layers through core.gemm.dense_q and the
                    matmul_q kernel op; core.precision holds the
                    quantize/dequantize machinery)
    kv_layout       serving KV cache layout: "dense" (one contiguous
                    max_len row per slot) | "paged" (page pool with
                    slot->page-table indirection and copy-on-write
                    prefix sharing, serving.kv_pool)
    quant_kv        KV-cache quantization: "off" | "int8" (int8 pages
                    + per-(position, head) f32 scales, dequantized on
                    the f32 accumulator inside the decode kernel;
                    paged layout only)

Because it is frozen and hashable it works as a jit static argument and
a custom_vjp nondiff argument: identical policies never retrace, and a
changed policy retraces exactly once.

Ambient default: `current_policy()` resolves, in order, the innermost
active `policy.scope()` on this thread, the process default set by
`set_default_policy()`, the REPRO_POLICY environment variable, and
finally `Policy()` (plain XLA). Legacy backend strings ("tuned",
"pallas_interpret", ...) map through `Policy.from_backend`; the old
string-kwarg call sites survive as deprecation shims that land here.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings
from typing import Optional

from repro.core import hw

#: Legacy string-backend spellings accepted by `Policy.from_backend`
#: (and therefore by every ``backend=`` deprecation shim and CLI flag).
LEGACY_BACKEND_NAMES = (
    "xla", "pallas", "pallas_interpret", "naive", "naive_interpret",
    "tuned", "tuned_interpret",
)

AUTOTUNE_MODES = ("off", "cached")

#: Policy-level quantization modes: "off" plus core.precision's
#: QUANT_MODES (kept as a literal here so this module stays jax-free;
#: tests/test_quant.py pins the two tuples against each other).
QUANT_MODES = ("off", "int8")

#: KV-cache layout: "dense" keeps one contiguous max_len row per slot
#: (PR 2); "paged" routes the serving cache through the page pool
#: (serving.kv_pool) with slot->page-table indirection and prefix
#: sharing, decoded by the paged flash kernel.
KV_LAYOUTS = ("dense", "paged")

#: KV-cache quantization: int8 pages with per-(position, head) f32
#: scales, quantized at page-write time and dequantized on the f32
#: accumulator inside the decode kernel. Requires kv_layout="paged"
#: (the dense cache never grew a scale plane; the engine enforces it).
QUANT_KV_MODES = ("off", "int8")

ENV_VAR = "REPRO_POLICY"


@dataclasses.dataclass(frozen=True)
class Policy:
    backend: str = "xla"
    interpret: Optional[bool] = None
    chip: hw.ChipSpec = hw.DEFAULT_CHIP
    autotune: str = "off"
    fuse_epilogues: bool = True
    out_dtype: Optional[str] = None
    quant: str = "off"
    kv_layout: str = "dense"
    quant_kv: str = "off"

    def __post_init__(self):
        if self.autotune not in AUTOTUNE_MODES:
            raise ValueError(
                f"unknown autotune mode {self.autotune!r}; "
                f"expected one of {AUTOTUNE_MODES}")
        if self.quant not in QUANT_MODES:
            raise ValueError(
                f"unknown quant mode {self.quant!r}; "
                f"expected one of {QUANT_MODES}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(
                f"unknown kv_layout {self.kv_layout!r}; "
                f"expected one of {KV_LAYOUTS}")
        if self.quant_kv not in QUANT_KV_MODES:
            raise ValueError(
                f"unknown quant_kv mode {self.quant_kv!r}; "
                f"expected one of {QUANT_KV_MODES}")
        if self.interpret is not None and not isinstance(self.interpret, bool):
            raise ValueError(f"interpret must be None or bool, "
                             f"got {self.interpret!r}")
        # `backend` is validated at dispatch time against the kernel
        # registry (kernels.registry.get_impl) so the error can list
        # exactly the implementations that are actually registered.

    # --- resolution -------------------------------------------------
    @property
    def resolved_interpret(self) -> bool:
        """interpret=None means "interpret unless this host is a real
        TPU" — the single source of truth the old per-call-site
        suffix-sniffing (`endswith("_interpret")`) collapsed into."""
        if self.interpret is not None:
            return self.interpret
        import jax  # deferred: keep `import repro` light
        return jax.devices()[0].platform != "tpu"

    @property
    def kernel_fingerprint(self) -> str:
        """The execution-relevant fields as a stable short string:
        "xla", "pallas", "pallas_interpret", "naive_interpret". Keys
        the autotuner cache (interpreter timings must never leak into
        compiled-TPU decisions) and matches the historical cache-key
        backend component, so existing tuning.json files stay valid:
        quant="off" (the historical state) adds nothing, while
        quant="int8" appends "_int8" — quantized-kernel winners get
        their own key population without invalidating old entries.
        kv_layout="paged" / quant_kv="int8" follow the same rule:
        defaults add nothing (old fingerprints stay byte-identical),
        non-defaults append "_paged" / "_kvint8"."""
        if self.backend == "xla":
            base = "xla"
        else:
            base = (f"{self.backend}_interpret" if self.resolved_interpret
                    else self.backend)
        if self.quant != "off":
            base = f"{base}_{self.quant}"
        if self.quant_kv != "off":
            base = f"{base}_kv{self.quant_kv}"
        if self.kv_layout != "dense":
            base = f"{base}_{self.kv_layout}"
        return base

    def fingerprint(self) -> str:
        """Full stable description — recorded in bench JSON
        (benchmarks.common.write_bench_json) and usable as REPRO_POLICY."""
        parts = [f"backend={self.backend}"]
        if self.interpret is not None:
            parts.append(f"interpret={str(self.interpret).lower()}")
        if self.chip is not hw.DEFAULT_CHIP:
            parts.append(f"chip={self.chip.name}")
        if self.autotune != "off":
            parts.append(f"autotune={self.autotune}")
        if not self.fuse_epilogues:
            parts.append("fuse_epilogues=false")
        if self.out_dtype is not None:
            parts.append(f"out_dtype={self.out_dtype}")
        if self.quant != "off":
            parts.append(f"quant={self.quant}")
        if self.kv_layout != "dense":
            parts.append(f"kv_layout={self.kv_layout}")
        if self.quant_kv != "off":
            parts.append(f"quant_kv={self.quant_kv}")
        return ",".join(parts)

    def resolved_out_dtype(self, fallback):
        return self.out_dtype if self.out_dtype is not None else fallback

    # --- derived policies -------------------------------------------
    def replace(self, **kw) -> "Policy":
        return dataclasses.replace(self, **kw)

    # --- ambient default --------------------------------------------
    @contextlib.contextmanager
    def scope(self):
        """Make this policy the ambient default on this thread:

            with Policy(backend="pallas").scope():
                gemm.matmul(a, b)        # runs the tiled kernel

        Scopes nest; the previous ambient policy is restored on exit
        (tests/test_policy.py pins the nesting/restore semantics)."""
        stack = _scope_stack()
        stack.append(self)
        try:
            yield self
        finally:
            stack.pop()

    # --- legacy spellings -------------------------------------------
    @classmethod
    def from_backend(cls, name: str) -> "Policy":
        """Map a legacy backend string onto the typed policy. "tuned"
        was never a kernel — it is the tiled Pallas kernel with cached
        tiles, i.e. autotune="cached" on the policy."""
        try:
            return _LEGACY[name]
        except KeyError:
            raise ValueError(
                f"unknown backend {name!r}; expected a Policy or one of "
                f"{LEGACY_BACKEND_NAMES}") from None

    @classmethod
    def parse(cls, spec: str) -> "Policy":
        """Parse a policy spec string: either a legacy backend name
        ("tuned_interpret") or comma-separated fields as produced by
        `fingerprint()` ("backend=pallas,interpret=true,autotune=cached").
        This is the REPRO_POLICY env-var format."""
        spec = spec.strip()
        if not spec:
            return cls()
        if "=" not in spec:
            return cls.from_backend(spec)
        kw = {}
        for item in spec.split(","):
            key, _, val = item.partition("=")
            key, val = key.strip(), val.strip()
            if key == "backend":
                kw[key] = val
            elif key in ("interpret", "fuse_epilogues"):
                if val.lower() not in ("true", "false", "1", "0"):
                    raise ValueError(f"policy field {key}={val!r}: "
                                     "expected true/false")
                kw[key] = val.lower() in ("true", "1")
            elif key == "autotune":
                kw[key] = val
            elif key == "out_dtype":
                kw[key] = val
            elif key in ("quant", "kv_layout", "quant_kv"):
                kw[key] = val
            elif key == "chip":
                try:
                    kw[key] = hw.CHIPS[val]
                except KeyError:
                    raise ValueError(
                        f"unknown chip {val!r}; expected one of "
                        f"{sorted(hw.CHIPS)}") from None
            else:
                raise ValueError(
                    f"unknown policy field {key!r} in {spec!r}; expected "
                    "backend/interpret/chip/autotune/fuse_epilogues/"
                    "out_dtype/quant/kv_layout/quant_kv")
        return cls(**kw)


_LEGACY = {
    "xla": Policy(),
    "pallas": Policy(backend="pallas", interpret=False),
    "pallas_interpret": Policy(backend="pallas", interpret=True),
    "naive": Policy(backend="naive", interpret=False),
    "naive_interpret": Policy(backend="naive", interpret=True),
    "tuned": Policy(backend="pallas", interpret=False, autotune="cached"),
    "tuned_interpret": Policy(backend="pallas", interpret=True,
                              autotune="cached"),
}


# ----------------------------------------------------------------------
# Ambient resolution
# ----------------------------------------------------------------------

_tls = threading.local()
_process_default: Optional[Policy] = None
_env_cache: tuple = (None, None)      # (env string, parsed Policy)


def _scope_stack() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def set_default_policy(policy: Optional[Policy]) -> None:
    """Set the process-wide default (None = back to env/xla). An active
    `scope()` still wins on its thread."""
    global _process_default
    if policy is not None and not isinstance(policy, Policy):
        raise TypeError(f"expected Policy or None, got {type(policy)}; "
                        "legacy strings go through Policy.from_backend")
    _process_default = policy


def current_policy() -> Policy:
    """Innermost scope() > set_default_policy() > $REPRO_POLICY > xla."""
    stack = getattr(_tls, "stack", None)
    if stack:
        return stack[-1]
    if _process_default is not None:
        return _process_default
    env = os.environ.get(ENV_VAR)
    if env:
        global _env_cache
        if _env_cache[0] != env:
            _env_cache = (env, Policy.parse(env))
        return _env_cache[1]
    return Policy()


def resolve(policy: Optional[Policy] = None,
            backend: Optional[str] = None) -> Policy:
    """The one resolution rule every dispatcher uses: explicit policy >
    legacy string kwarg (deprecation shim) > ambient default."""
    if policy is not None:
        if isinstance(policy, str):
            # tolerated spelling: policy="pallas_interpret" — parsed,
            # not deprecated (the string is an explicit policy spec).
            return Policy.parse(policy)
        if not isinstance(policy, Policy):
            raise TypeError(f"policy must be a Policy, got {type(policy)}")
        return policy
    if backend is not None:
        warn_deprecated(
            "backend_kwarg",
            "string backend= kwargs are deprecated; pass "
            "policy=Policy.from_backend(name) (or enter "
            "Policy(...).scope()) instead")
        return Policy.from_backend(backend)
    return current_policy()


# ----------------------------------------------------------------------
# Deprecation plumbing (warn once per shim, resettable for tests)
# ----------------------------------------------------------------------

_warned: set = set()


def warn_deprecated(key: str, message: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    """Test hook: make every shim warn again."""
    _warned.clear()
