"""Synthetic mixed-length workload generator.

One trace builder shared by the serving CLI (launch/serve.py) and the
serving benchmark (benchmarks/bench_serving.py) so "the same trace
parameters" always mean the same workload: prompt lengths uniform over
an INCLUSIVE [lo, hi] range, arrivals Poisson at `arrival_rate` req/s
(0 = burst, everything at t=0), random-token prompts, and — for encdec
archs — a synthetic encoder-frame block per request.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

TraceItem = Tuple[np.ndarray, int, float, Optional[np.ndarray]]
#                 (prompt, max_new_tokens, arrival_time, enc_frames)


def synthetic_trace(cfg, n: int, *, rng: np.random.Generator,
                    len_range: Tuple[int, int] = (8, 48), gen: int = 16,
                    arrival_rate: float = 0.0) -> List[TraceItem]:
    lo, hi = len_range
    assert 1 <= lo <= hi, len_range
    lens = rng.integers(lo, hi + 1, n)
    arrivals = (np.cumsum(rng.exponential(1.0 / arrival_rate, n))
                if arrival_rate > 0 else np.zeros(n))
    trace: List[TraceItem] = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab, int(lens[i])).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        trace.append((prompt, gen, float(arrivals[i]), enc))
    return trace
