"""Synthetic workload generators.

Trace builders shared by the serving CLI (launch/serve.py) and the
serving benchmark (benchmarks/bench_serving.py) so "the same trace
parameters" always mean the same workload:

* synthetic_trace — mixed-length: prompt lengths uniform over an
  INCLUSIVE [lo, hi] range, arrivals Poisson at `arrival_rate` req/s
  (0 = burst, everything at t=0), random-token prompts, and — for
  encdec archs — a synthetic encoder-frame block per request.
* prefix_heavy_trace — chat-shaped: every request opens with the SAME
  `prefix_len`-token system prompt followed by a short random suffix.
  This is the workload where the paged KV cache's prefix sharing pays:
  N requests pin one copy of the prefix pages instead of N.

Both traces optionally carry per-request fault-tolerance fields:

* ``deadline`` (relative seconds after arrival — the TraceItem stores
  the ABSOLUTE engine-clock deadline, ready for ``engine.submit``) and
  ``priority_levels`` (uniform choice per request; higher outranks
  lower in the engine's preemption victim selection).
* ``burst_size > 1`` switches the arrival process to bursty: requests
  arrive in groups of `burst_size` that hit the engine simultaneously,
  with exponential gaps between groups scaled so the long-run rate
  still equals `arrival_rate` — the pool-exhaustion worst case that a
  smooth Poisson trace never produces.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np


class TraceItem(NamedTuple):
    prompt: np.ndarray
    gen: int
    arrival: float
    enc_frames: Optional[np.ndarray] = None
    deadline: Optional[float] = None       # absolute engine-clock seconds
    priority: int = 0


def _arrivals(rng: np.random.Generator, n: int, arrival_rate: float,
              burst_size: int) -> np.ndarray:
    """Arrival times: Poisson gaps per request, or — with burst_size > 1
    — per *group* of simultaneous requests, gap mean scaled by the
    group size so the long-run request rate is unchanged."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    if arrival_rate <= 0:
        return np.zeros(n)
    if burst_size == 1:
        return np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    n_bursts = -(-n // burst_size)
    times = np.cumsum(rng.exponential(burst_size / arrival_rate, n_bursts))
    return np.repeat(times, burst_size)[:n]


def _priorities(rng: np.random.Generator, n: int,
                priority_levels: Sequence[int]) -> np.ndarray:
    levels = np.asarray(list(priority_levels), np.int64)
    if levels.size == 0:
        raise ValueError("priority_levels must be non-empty")
    return levels[rng.integers(0, levels.size, n)]


def synthetic_trace(cfg, n: int, *, rng: np.random.Generator,
                    len_range: Tuple[int, int] = (8, 48), gen: int = 16,
                    arrival_rate: float = 0.0,
                    deadline: Optional[float] = None,
                    priority_levels: Sequence[int] = (0,),
                    burst_size: int = 1) -> List[TraceItem]:
    lo, hi = len_range
    if not 1 <= lo <= hi:
        raise ValueError(f"bad len_range {len_range}")
    lens = rng.integers(lo, hi + 1, n)
    arrivals = _arrivals(rng, n, arrival_rate, burst_size)
    prios = _priorities(rng, n, priority_levels)
    trace: List[TraceItem] = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab, int(lens[i])).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        dl = None if deadline is None else float(arrivals[i]) + deadline
        trace.append(TraceItem(prompt, gen, float(arrivals[i]), enc,
                               dl, int(prios[i])))
    return trace


def prefix_heavy_trace(cfg, n: int, *, rng: np.random.Generator,
                       prefix_len: int = 32,
                       suffix_range: Tuple[int, int] = (2, 12),
                       gen: int = 8,
                       arrival_rate: float = 0.0,
                       deadline: Optional[float] = None,
                       priority_levels: Sequence[int] = (0,),
                       burst_size: int = 1) -> List[TraceItem]:
    """N requests sharing one `prefix_len`-token system prompt, each
    with a uniform [lo, hi] random-token suffix (hi inclusive; lo may be
    0 — identical prompts, the copy-on-write worst case). Arrival,
    deadline and priority models match synthetic_trace."""
    lo, hi = suffix_range
    if not 0 <= lo <= hi:
        raise ValueError(f"bad suffix_range {suffix_range}")
    if prefix_len < 1:
        raise ValueError(f"prefix_len must be >= 1, got {prefix_len}")
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    lens = rng.integers(lo, hi + 1, n)
    arrivals = _arrivals(rng, n, arrival_rate, burst_size)
    prios = _priorities(rng, n, priority_levels)
    trace: List[TraceItem] = []
    for i in range(n):
        suffix = rng.integers(0, cfg.vocab, int(lens[i])).astype(np.int32)
        prompt = np.concatenate([prefix, suffix])
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        dl = None if deadline is None else float(arrivals[i]) + deadline
        trace.append(TraceItem(prompt, gen, float(arrivals[i]), enc,
                               dl, int(prios[i])))
    return trace
