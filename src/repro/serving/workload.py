"""Synthetic workload generators — a registry of serving scenarios.

Trace builders shared by the serving CLI (launch/serve.py) and the
serving benchmark (benchmarks/bench_serving.py) so "the same trace
parameters" always mean the same workload. All scenarios share one
body (`_make_trace`: arrivals, priorities, deadlines, encdec frames)
and differ only in how each request's prompt and generation budget are
drawn; the ``TRACES`` registry keys them by name so the CLI's
``--workload`` flag and the benchmark's per-scenario table resolve
through a single source of truth:

* ``mixed`` (synthetic_trace) — prompt lengths uniform over an
  INCLUSIVE [lo, hi] range, random tokens. The uniform baseline.
* ``prefix_heavy`` (prefix_heavy_trace) — chat-shaped: every request
  opens with the SAME `prefix_len`-token system prompt plus a short
  random suffix. Where paged prefix sharing pays — and where a draft
  model's proposals track the target best (speculation wins here).
* ``bursty`` (bursty_trace) — compound Poisson arrivals: group sizes
  are 1 + Poisson(burst_mean - 1), groups land simultaneously with
  exponential gaps scaled to preserve the long-run request rate. The
  pool-exhaustion / preemption stress a smooth trace never produces.
* ``long_context`` (long_context_trace) — long prompts, short
  generations: prefill-bound traffic where decode-side wins (paging,
  speculation) matter least and admission latency dominates.

Every scenario optionally carries per-request fault-tolerance fields:
``deadline`` (relative seconds after arrival — the TraceItem stores the
ABSOLUTE engine-clock deadline, ready for ``engine.submit``) and
``priority_levels`` (uniform choice per request; higher outranks lower
in the engine's preemption victim selection). ``burst_size > 1`` on the
fixed-size-burst scenarios groups arrivals the same way older revisions
did (kept for the chaos suite's worst cases).
"""

from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, \
    Tuple

import numpy as np


class TraceItem(NamedTuple):
    prompt: np.ndarray
    gen: int
    arrival: float
    enc_frames: Optional[np.ndarray] = None
    deadline: Optional[float] = None       # absolute engine-clock seconds
    priority: int = 0


def _arrivals(rng: np.random.Generator, n: int, arrival_rate: float,
              burst_size: int) -> np.ndarray:
    """Arrival times: Poisson gaps per request, or — with burst_size > 1
    — per *group* of simultaneous requests, gap mean scaled by the
    group size so the long-run request rate is unchanged."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    if arrival_rate <= 0:
        return np.zeros(n)
    if burst_size == 1:
        return np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    n_bursts = -(-n // burst_size)
    times = np.cumsum(rng.exponential(burst_size / arrival_rate, n_bursts))
    return np.repeat(times, burst_size)[:n]


def _compound_arrivals(rng: np.random.Generator, n: int,
                       arrival_rate: float, burst_mean: float) -> np.ndarray:
    """Compound Poisson arrivals: burst sizes 1 + Poisson(burst_mean-1)
    (so the mean group size is burst_mean and no group is empty), each
    group simultaneous, exponential inter-group gaps with mean
    burst_mean / arrival_rate — the long-run REQUEST rate stays
    `arrival_rate` while the instantaneous load swings."""
    if burst_mean < 1:
        raise ValueError(f"burst_mean must be >= 1, got {burst_mean}")
    if arrival_rate <= 0:
        return np.zeros(n)
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        t += float(rng.exponential(burst_mean / arrival_rate))
        size = 1 + int(rng.poisson(burst_mean - 1.0))
        out.extend([t] * size)
    return np.asarray(out[:n])


def _priorities(rng: np.random.Generator, n: int,
                priority_levels: Sequence[int]) -> np.ndarray:
    levels = np.asarray(list(priority_levels), np.int64)
    if levels.size == 0:
        raise ValueError("priority_levels must be non-empty")
    return levels[rng.integers(0, levels.size, n)]


def _make_trace(cfg, n: int, rng: np.random.Generator, prompt_fn, gen,
                *, arrival_rate: float, deadline: Optional[float],
                priority_levels: Sequence[int], burst_size: int = 1,
                arrivals: Optional[np.ndarray] = None) -> List[TraceItem]:
    """The shared trace body: every scenario is `prompt_fn(i) -> prompt`
    plus a per-request generation budget (int, or `gen(i) -> int`) over
    common arrival / deadline / priority / encdec-frame machinery."""
    if arrivals is None:
        arrivals = _arrivals(rng, n, arrival_rate, burst_size)
    prios = _priorities(rng, n, priority_levels)
    gen_fn = gen if callable(gen) else (lambda i: gen)
    trace: List[TraceItem] = []
    for i in range(n):
        prompt = np.asarray(prompt_fn(i), np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        dl = None if deadline is None else float(arrivals[i]) + deadline
        trace.append(TraceItem(prompt, int(gen_fn(i)), float(arrivals[i]),
                               enc, dl, int(prios[i])))
    return trace


def synthetic_trace(cfg, n: int, *, rng: np.random.Generator,
                    len_range: Tuple[int, int] = (8, 48), gen: int = 16,
                    arrival_rate: float = 0.0,
                    deadline: Optional[float] = None,
                    priority_levels: Sequence[int] = (0,),
                    burst_size: int = 1) -> List[TraceItem]:
    """Mixed-length uniform baseline (registry name: "mixed")."""
    lo, hi = len_range
    if not 1 <= lo <= hi:
        raise ValueError(f"bad len_range {len_range}")
    lens = rng.integers(lo, hi + 1, n)
    return _make_trace(
        cfg, n, rng,
        lambda i: rng.integers(0, cfg.vocab, int(lens[i])), gen,
        arrival_rate=arrival_rate, deadline=deadline,
        priority_levels=priority_levels, burst_size=burst_size)


def prefix_heavy_trace(cfg, n: int, *, rng: np.random.Generator,
                       prefix_len: int = 32,
                       suffix_range: Tuple[int, int] = (2, 12),
                       gen: int = 8,
                       arrival_rate: float = 0.0,
                       deadline: Optional[float] = None,
                       priority_levels: Sequence[int] = (0,),
                       burst_size: int = 1) -> List[TraceItem]:
    """N requests sharing one `prefix_len`-token system prompt, each
    with a uniform [lo, hi] random-token suffix (hi inclusive; lo may be
    0 — identical prompts, the copy-on-write worst case)."""
    lo, hi = suffix_range
    if not 0 <= lo <= hi:
        raise ValueError(f"bad suffix_range {suffix_range}")
    if prefix_len < 1:
        raise ValueError(f"prefix_len must be >= 1, got {prefix_len}")
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    lens = rng.integers(lo, hi + 1, n)
    return _make_trace(
        cfg, n, rng,
        lambda i: np.concatenate(
            [prefix, rng.integers(0, cfg.vocab, int(lens[i]))
             .astype(np.int32)]), gen,
        arrival_rate=arrival_rate, deadline=deadline,
        priority_levels=priority_levels, burst_size=burst_size)


def bursty_trace(cfg, n: int, *, rng: np.random.Generator,
                 len_range: Tuple[int, int] = (8, 48), gen: int = 16,
                 arrival_rate: float = 0.0, burst_mean: float = 4.0,
                 deadline: Optional[float] = None,
                 priority_levels: Sequence[int] = (0,)) -> List[TraceItem]:
    """Compound-Poisson arrivals (random group sizes, simultaneous
    within a group) over mixed-length prompts — the admission-pressure
    scenario; rate-preserving, so only the VARIANCE differs vs
    "mixed"."""
    lo, hi = len_range
    if not 1 <= lo <= hi:
        raise ValueError(f"bad len_range {len_range}")
    lens = rng.integers(lo, hi + 1, n)
    arrivals = _compound_arrivals(rng, n, arrival_rate, burst_mean)
    return _make_trace(
        cfg, n, rng,
        lambda i: rng.integers(0, cfg.vocab, int(lens[i])), gen,
        arrival_rate=arrival_rate, deadline=deadline,
        priority_levels=priority_levels, arrivals=arrivals)


def long_context_trace(cfg, n: int, *, rng: np.random.Generator,
                       len_range: Tuple[int, int] = (96, 160),
                       gen: int = 4,
                       arrival_rate: float = 0.0,
                       deadline: Optional[float] = None,
                       priority_levels: Sequence[int] = (0,),
                       burst_size: int = 1) -> List[TraceItem]:
    """Long prompts, short generations: prefill-bound traffic (summarize
    / extract shapes). Decode-side machinery matters least here — the
    scenario exists so per-scenario percentiles show WHERE speculation
    and paging pay, not just that they do."""
    lo, hi = len_range
    if not 1 <= lo <= hi:
        raise ValueError(f"bad len_range {len_range}")
    lens = rng.integers(lo, hi + 1, n)
    return _make_trace(
        cfg, n, rng,
        lambda i: rng.integers(0, cfg.vocab, int(lens[i])), gen,
        arrival_rate=arrival_rate, deadline=deadline,
        priority_levels=priority_levels, burst_size=burst_size)


#: Scenario registry: name -> trace builder with the uniform
#: ``(cfg, n, *, rng, **kwargs)`` signature. serve.py's ``--workload``
#: and bench_serving.py's scenario loop both resolve through this.
TRACES: Dict[str, Callable[..., List[TraceItem]]] = {
    "mixed": synthetic_trace,
    "prefix_heavy": prefix_heavy_trace,
    "bursty": bursty_trace,
    "long_context": long_context_trace,
}


def get_trace(name: str) -> Callable[..., List[TraceItem]]:
    try:
        return TRACES[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r}; registered: "
            f"{', '.join(sorted(TRACES))}") from None


def make_trace(name: str, cfg, n: int, *, rng: np.random.Generator,
               **kwargs) -> List[TraceItem]:
    """Build the named scenario's trace (see ``TRACES``)."""
    return get_trace(name)(cfg, n, rng=rng, **kwargs)
