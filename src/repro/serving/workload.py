"""Synthetic workload generators.

Trace builders shared by the serving CLI (launch/serve.py) and the
serving benchmark (benchmarks/bench_serving.py) so "the same trace
parameters" always mean the same workload:

* synthetic_trace — mixed-length: prompt lengths uniform over an
  INCLUSIVE [lo, hi] range, arrivals Poisson at `arrival_rate` req/s
  (0 = burst, everything at t=0), random-token prompts, and — for
  encdec archs — a synthetic encoder-frame block per request.
* prefix_heavy_trace — chat-shaped: every request opens with the SAME
  `prefix_len`-token system prompt followed by a short random suffix.
  This is the workload where the paged KV cache's prefix sharing pays:
  N requests pin one copy of the prefix pages instead of N.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

TraceItem = Tuple[np.ndarray, int, float, Optional[np.ndarray]]
#                 (prompt, max_new_tokens, arrival_time, enc_frames)


def synthetic_trace(cfg, n: int, *, rng: np.random.Generator,
                    len_range: Tuple[int, int] = (8, 48), gen: int = 16,
                    arrival_rate: float = 0.0) -> List[TraceItem]:
    lo, hi = len_range
    assert 1 <= lo <= hi, len_range
    lens = rng.integers(lo, hi + 1, n)
    arrivals = (np.cumsum(rng.exponential(1.0 / arrival_rate, n))
                if arrival_rate > 0 else np.zeros(n))
    trace: List[TraceItem] = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab, int(lens[i])).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        trace.append((prompt, gen, float(arrivals[i]), enc))
    return trace


def prefix_heavy_trace(cfg, n: int, *, rng: np.random.Generator,
                       prefix_len: int = 32,
                       suffix_range: Tuple[int, int] = (2, 12),
                       gen: int = 8,
                       arrival_rate: float = 0.0) -> List[TraceItem]:
    """N requests sharing one `prefix_len`-token system prompt, each
    with a uniform [lo, hi] random-token suffix (hi inclusive; lo may be
    0 — identical prompts, the copy-on-write worst case). Arrival model
    matches synthetic_trace."""
    lo, hi = suffix_range
    assert 0 <= lo <= hi, suffix_range
    assert prefix_len >= 1, prefix_len
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    lens = rng.integers(lo, hi + 1, n)
    arrivals = (np.cumsum(rng.exponential(1.0 / arrival_rate, n))
                if arrival_rate > 0 else np.zeros(n))
    trace: List[TraceItem] = []
    for i in range(n):
        suffix = rng.integers(0, cfg.vocab, int(lens[i])).astype(np.int32)
        prompt = np.concatenate([prefix, suffix])
        enc = None
        if cfg.family == "encdec":
            enc = rng.normal(size=(cfg.enc_ctx, cfg.d_model)) \
                .astype(np.float32)
        trace.append((prompt, gen, float(arrivals[i]), enc))
    return trace
