"""Deterministic fault injection for the serving engine.

Modeled on ``distributed.fault_tolerance.FailureInjector`` (step-count
scripted, fire-once), but aimed at the serving failure modes: poisoned
logits rows, corrupted KV pages, kernel-level faults, slow steps, and
forced pool exhaustion. Every fault fires at a *scripted* decode-step /
admission ordinal, so recovery paths are pinned by deterministic tests
instead of anecdotes — the injector never consults a clock or an RNG.

Hook points (all driven by the engine, see serving/engine.py):

  ``poison_rows(step, rows, slots)``   NaN the scripted slots' logits
                                       rows after the device step — the
                                       numeric sentinel must quarantine
                                       exactly those slots.
  ``corrupt_slots(step, slots)``       which active slots should have a
                                       privately-owned cache page
                                       NaN-poisoned *before* the step
                                       (the fault then surfaces through
                                       real attention math).
  ``before_kernel(step)``              raises SimulatedKernelFault at
                                       scripted steps (exercising the
                                       retry -> degrade-to-xla path) and
                                       sleeps at scripted slow steps
                                       (exercising straggler flagging).
  ``deny_admission(ordinal)``          True at scripted admission
                                       ordinals: the engine treats the
                                       KV pool as exhausted, forcing the
                                       preempt-or-defer path without
                                       having to size a pool tightly.

Each scripted entry fires at most once (like FailureInjector's
``fail_once``), so a retried step succeeds and the recovery — not the
fault — is what the test observes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

__all__ = ["FaultInjector", "SimulatedKernelFault"]


class SimulatedKernelFault(RuntimeError):
    """Injected stand-in for a kernel-level failure (bad lowering,
    device OOM, miscompiled tile) raised by the jitted decode step."""


def _as_slot_map(spec) -> Dict[int, Tuple[int, ...]]:
    """Normalize {step: slot | (slots...)} to {step: (slots...)}."""
    out: Dict[int, Tuple[int, ...]] = {}
    for step, slots in dict(spec or {}).items():
        if isinstance(slots, (int, np.integer)):
            slots = (int(slots),)
        out[int(step)] = tuple(int(s) for s in slots)
    return out


@dataclasses.dataclass
class FaultInjector:
    """Scripted serving faults. All schedules key on the engine's decode
    step counter (0-based) except ``deny_admissions``, which keys on the
    admission ordinal (0-based count of successful admissions so far)."""

    nan_rows: Mapping[int, object] = dataclasses.field(default_factory=dict)
    corrupt_pages: Mapping[int, object] = dataclasses.field(
        default_factory=dict)
    kernel_fail_steps: Sequence[int] = ()
    slow_steps: Mapping[int, float] = dataclasses.field(default_factory=dict)
    deny_admissions: Sequence[int] = ()

    def __post_init__(self):
        self.nan_rows = _as_slot_map(self.nan_rows)
        self.corrupt_pages = _as_slot_map(self.corrupt_pages)
        self.kernel_fail_steps = tuple(int(s) for s in self.kernel_fail_steps)
        self.slow_steps = {int(k): float(v)
                          for k, v in dict(self.slow_steps).items()}
        self.deny_admissions = tuple(int(a) for a in self.deny_admissions)
        self._fired: set = set()
        self.counts = {"nan_rows": 0, "page_corruptions": 0,
                       "kernel_faults": 0, "slow_steps": 0,
                       "denied_admissions": 0}

    def _fire(self, key) -> bool:
        if key in self._fired:
            return False
        self._fired.add(key)
        return True

    # ---------------------------------------------------------- hooks

    def poison_rows(self, step: int, rows: np.ndarray,
                    slots: Sequence[int]) -> np.ndarray:
        """NaN the scripted slots' logits rows at `step`. Returns `rows`
        untouched when nothing is scripted, else a poisoned copy (the
        engine's logits view is read-only)."""
        todo = [s for s in self.nan_rows.get(step, ())
                if s in slots and ("nan", step, s) not in self._fired]
        if not todo:
            return rows
        rows = np.array(rows)
        for slot in todo:
            self._fire(("nan", step, slot))
            rows[slot] = np.nan
            self.counts["nan_rows"] += 1
        return rows

    def corrupt_slots(self, step: int,
                      slots: Sequence[int]) -> Tuple[int, ...]:
        """Active slots whose cache page the engine should poison
        before running decode step `step`."""
        hit = []
        for slot in self.corrupt_pages.get(step, ()):
            if slot in slots and self._fire(("page", step, slot)):
                hit.append(slot)
                self.counts["page_corruptions"] += 1
        return tuple(hit)

    def before_kernel(self, step: int) -> None:
        """Called immediately before the jitted decode step."""
        if step in self.slow_steps and self._fire(("slow", step)):
            self.counts["slow_steps"] += 1
            time.sleep(self.slow_steps[step])
        if step in self.kernel_fail_steps and self._fire(("kernel", step)):
            self.counts["kernel_faults"] += 1
            raise SimulatedKernelFault(
                f"injected kernel fault at decode step {step}")

    def deny_admission(self, ordinal: int) -> bool:
        """True when admission `ordinal` is scripted to see an exhausted
        pool (fires once per ordinal)."""
        if ordinal in self.deny_admissions and self._fire(("deny", ordinal)):
            self.counts["denied_admissions"] += 1
            return True
        return False

    # --------------------------------------------------------- report

    def report(self) -> Dict[str, int]:
        return dict(self.counts)
