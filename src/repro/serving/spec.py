"""Speculative decoding: the draft half of the draft/verify engine.

Sequential one-token decode is the last serialized hot path in the
serving engine — every emitted token costs one full target-model pass
whose GEMMs are too thin to saturate the device. Speculative decoding
restructures that work the way the paper restructures everything else:
a cheap draft model proposes ``k`` tokens autoregressively, then the
target model scores all ``k+1`` positions in ONE prefill-shaped forward
(``model.verify_step``) that rides the tuned/fused/quantized kernel
stack at real arithmetic intensity. The standard leftover/residual
acceptance rule (``sampler.Sampler.speculative_accept``) keeps the
emitted stream distribution-identical to decoding the target alone —
and token-exact for greedy sampling, which is what the differential
tests pin.

``SpecDecoder`` owns everything draft-side:

* the draft model's config/params under its OWN execution policy (the
  draft may run int8 weights while the target serves dense — policy
  fingerprints keep their tuning caches separate for free). Draft KV
  state is always a DENSE per-slot cache: rollback then needs no page
  bookkeeping at all, because rollback is purely positional (below).
* per-slot admission prefill (same bucketing as the engine's) filling
  the draft cache with the slot's context, and
* ``draft_round``: ``spec_k + 1`` masked one-token draft steps over all
  slots that propose the draft tokens AND keep the draft cache's rows
  aligned with every acceptance outcome in advance.

Rollback is positional, not transactional. A round at position ``pos``
feeds (pending, d_1 .. d_k) at ``pos .. pos+k``, so draft rows
``pos .. pos+a`` hold exactly the tokens the target accepted for ANY
acceptance count ``a`` — the rows past the new pending position are
stale, but stale rows are (1) never attended, because each step masks
``kv_len = pos + 1`` at its own depth, and (2) always overwritten
before they could become valid, because the next round's feeds start at
the new pending position. The engine's target cache relies on the same
invariant after a rejection (verify wrote k+1 rows, fewer were
consumed), and on preemption the resume path re-prefills both caches
from the request's full context (recompute-on-resume, PR 8).
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as _pol
from repro.models import model as M
from repro.serving.sampler import Sampler
from repro.training import train_loop as TL

__all__ = ["SpecDecoder"]


class SpecDecoder:
    """Draft-model runner for speculative decoding.

    Parameters
    ----------
    cfg, params:    the DRAFT model (any dense/moe/vlm config whose
                    vocab matches the target's).
    max_slots:      must equal the engine's slot count (shared slot ids).
    max_len:        the engine's (already rounded) max_len; the draft
                    cache adds ``spec_k`` rows of headroom because a
                    round writes up to ``pos + spec_k``.
    spec_k:         draft tokens proposed per round.
    policy:         draft execution policy. kv_layout must be "dense" —
                    the draft cache is per-slot rows by design (see
                    module docstring); quant="int8" weights are fine.
    sampler:        draft proposal sampler (default greedy — a greedy
                    draft is a valid ``q`` under ANY target sampler:
                    its distribution is the delta at the argmax).
    """

    def __init__(self, cfg, params, *, max_slots: int, max_len: int,
                 spec_k: int = 4, policy=None,
                 sampler: Optional[Sampler] = None,
                 prefill_chunk: int = 8):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"draft model must be an attention-cache family "
                f"(dense/moe/vlm), not {cfg.family!r}")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.cfg = cfg
        self.policy = _pol.resolve(policy)
        if self.policy.kv_layout != "dense":
            raise ValueError(
                "the draft KV cache is dense by design (positional "
                "rollback needs no page bookkeeping); pass a draft "
                "policy with kv_layout='dense'")
        if self.policy.quant == "int8":
            params = M.quantize_params(params)
        self.params = params
        self.spec_k = spec_k
        self.max_slots = max_slots
        self.prefill_chunk = max(1, prefill_chunk)
        self.sampler = sampler or Sampler()
        # headroom: a round writes rows pos .. pos+spec_k; chunked
        # attention wants lengths beyond attn_chunk to be multiples.
        a = cfg.attn_chunk
        ml = max_len + spec_k
        if ml > a and ml % a:
            ml += a - ml % a
        self.max_len = ml

        self.cache = M.init_cache(cfg, max_slots, ml)
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(
            self.cache)
        small = M.init_cache(cfg, 1, ml)
        from repro.serving.engine import _slot_axis
        self._slot_axes = [
            _slot_axis(b.shape, s.shape, name=jax.tree_util.keystr(path))
            for (path, b), s in zip(flat, jax.tree.leaves(small))]
        self._write = jax.jit(self._write_slot, donate_argnums=(0,))
        self._prefill = jax.jit(TL.make_prefill(cfg, policy=self.policy),
                                donate_argnums=(2,))
        self._step = jax.jit(TL.make_serve_step(cfg, policy=self.policy),
                             donate_argnums=(3,))
        self.draft_time = 0.0          # seconds inside draft rounds
        self.prefill_time = 0.0        # seconds inside draft admission

    # -- cache plumbing (the engine's dense-slot copy, draft-side) ------
    def _write_slot(self, cache, sub, slot):
        leaves = jax.tree.leaves(cache)
        subs = jax.tree.leaves(sub)
        out = []
        for leaf, s, ax in zip(leaves, subs, self._slot_axes):
            if ax is None:
                out.append(s.astype(leaf.dtype))
                continue
            start = [0] * leaf.ndim
            start[ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                leaf, s.astype(leaf.dtype), tuple(start)))
        return jax.tree.unflatten(self._treedef, out)

    # -- admission ------------------------------------------------------
    def admit(self, slot: int, ctx: np.ndarray) -> None:
        """Prefill the slot's context into the draft cache (rows
        0..len(ctx)-1). Same bucketed batch-1 prefill as the engine's
        admission, so mixed prompt lengths stay on a bounded compile
        count. Called on every (re-)admission — a resumed request's
        fuller context simply overwrites the stale rows."""
        ctx = np.asarray(ctx, np.int32).reshape(-1)
        L = len(ctx)
        t0 = time.perf_counter()
        chunk = self.prefill_chunk
        lb = L - (L % chunk) or L
        batch = {"tokens": jnp.asarray(ctx[None, :lb])}
        sub = M.init_cache(self.cfg, 1, self.max_len)
        _, sub = self._prefill(self.params, batch, sub)
        for i in range(lb, L):         # remainder: one-token steps
            _, sub = self._step(self.params, jnp.asarray(ctx[None, None, i]),
                                jnp.int32(i), sub)
        self.cache = self._write(self.cache, sub, slot)
        self.prefill_time += time.perf_counter() - t0

    # -- the draft round ------------------------------------------------
    def draft_round(self, tokens: np.ndarray, pos: np.ndarray,
                    k_vec: np.ndarray):
        """Propose up to ``k_vec[s]`` draft tokens per slot.

        tokens: (S, 1) pending token per slot; pos: (S,) its position
        (< 0 = inactive slot); k_vec: (S,) draft count per slot (a slot
        near its generation budget proposes fewer than spec_k).

        Runs ``spec_k + 1`` one-token draft steps — step i feeds the
        last token (pending for i=0, else d_i) at ``pos + i`` for every
        slot with ``i <= k_vec[s]``, masked to pos = -1 elsewhere. The
        one-past-the-last feed writes d_k's own KV row so a fully
        accepted round leaves the draft cache complete up to the bonus
        token's position (no post-hoc fixup, no dpos bookkeeping).

        Returns (drafts (S, spec_k) int32, qprobs) where qprobs is
        (S, spec_k, vocab) draft distributions for a stochastic draft
        sampler, or None for a deterministic (greedy) one.
        """
        pos = np.asarray(pos, np.int32)
        k_vec = np.asarray(k_vec, np.int32)
        s_n = pos.shape[0]
        k = self.spec_k
        drafts = np.zeros((s_n, k), np.int32)
        qprobs = None
        if self.sampler.config.kind != "greedy":
            qprobs = np.zeros((s_n, k, self.cfg.vocab), np.float64)
        cur = np.array(tokens, np.int32).reshape(s_n, 1)
        t0 = time.perf_counter()
        for i in range(k + 1):
            pos_i = np.where((pos >= 0) & (i <= k_vec),
                             pos + i, -1).astype(np.int32)
            logits, self.cache = self._step(
                self.params, jnp.asarray(cur), jnp.asarray(pos_i),
                self.cache)
            if i == k:
                break                  # final feed is KV-write only
            rows = np.asarray(logits)[:, -1, :self.cfg.vocab]
            for s in range(s_n):
                if pos[s] >= 0 and i < k_vec[s]:
                    tok = self.sampler(rows[s])
                    drafts[s, i] = tok
                    if qprobs is not None:
                        qprobs[s, i] = self.sampler.probs(rows[s])
                    cur[s, 0] = tok
        self.draft_time += time.perf_counter() - t0
        return drafts, qprobs
