"""Continuous-batching serving engine over a fixed pool of cache slots.

Request lifecycle (one slot = one batch row of the jitted step):

        submit            slot free & arrived          len == max_new
    req ------> WAITING ----------------------> ACTIVE --------------> FINISHED
                          admit = prefill(1xL)         evict: pos[slot] = -1,
                          + copy into slot row         slot back in free pool

Every decode step runs ONE jitted serve_step over ALL slots with a
per-slot position vector `pos: (S,) int32` — heterogeneous requests
(different prompt lengths, admitted at different times) share the same
compiled program. Inactive slots carry pos = -1: the model masks their
cache writes and their logits are discarded, so idle rows cost FLOPs
but never correctness (the fixed batch shape is what keeps one XLA
executable serving the whole trace).

Admission prefills the prompt at batch size 1 into a fresh single-slot
cache, then copies that cache into the slot's row of the pooled cache.
Prompt lengths are bucketed down to a multiple of `prefill_chunk` for
the jitted prefill (bounding compile count under mixed-length traffic);
the 0..chunk-1 remainder tokens run through the same serve_step at
batch 1, so the admitted state is exactly what a full-length prefill
would have produced — tests/test_serving.py asserts token-exactness.

Family notes: attention caches copy per-slot KV rows; ssm/hybrid copy
recurrent state rows (their "position" is implicit in the state, the
pos vector only drives the attention members and bookkeeping). MoE is
served but not token-exact vs. an isolated run by construction: expert
capacity is contended by whichever tokens share the decode batch.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as _pol
from repro.models import model as M
from repro.serving.request import FINISHED, Request, percentile
from repro.serving.sampler import Sampler
from repro.serving.scheduler import SlotScheduler
from repro.training import train_loop as TL

# Admission prefill buckets prompt lengths down to a multiple of this
# (remainder tokens run through one-token steps) to bound compile count.
DEFAULT_PREFILL_CHUNK = 8


def _slot_axis(big_shape, small_shape):
    """Axis along which a cache leaf indexes slots: the axis where the
    max_slots-sized cache differs from the 1-slot cache. None = the leaf
    has no slot axis distinguishable (max_slots == 1: replace whole)."""
    diffs = [i for i, (a, b) in enumerate(zip(big_shape, small_shape))
             if a != b]
    if not diffs:
        return None
    assert len(diffs) == 1, (big_shape, small_shape)
    return diffs[0]


class ServingEngine:
    def __init__(self, cfg, params, *, max_slots: int, max_len: int,
                 sampler: Optional[Sampler] = None,
                 prefill_chunk: int = DEFAULT_PREFILL_CHUNK,
                 eos_id: Optional[int] = None, policy=None):
        self.cfg = cfg
        # Execution policy for every jitted step this engine compiles —
        # captured once at construction (explicit arg > ambient default)
        # so a later ambient change can never retrace a live engine
        # under different kernels.
        self.policy = _pol.resolve(policy)
        # quant="int8" policies quantize the dense weights ONCE here —
        # every jitted step then streams int8 weight tiles (the 2-4x
        # weight-traffic cut is the whole point of serving quantized);
        # embeddings and routers stay full precision (model.QUANT_EXCLUDE).
        if self.policy.quant == "int8":
            params = M.quantize_params(params)
        self.params = params
        self.max_slots = max_slots
        # chunked_attention requires kv lengths beyond attn_chunk to be
        # chunk multiples; max_len is trace-dependent, so round it up.
        a = cfg.attn_chunk
        if max_len > a and max_len % a:
            max_len += a - max_len % a
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.eos_id = eos_id
        self.sampler = sampler or Sampler()
        self.scheduler = SlotScheduler(max_slots)

        self.cache = M.init_cache(cfg, max_slots, max_len)
        big_leaves, self._treedef = jax.tree.flatten(self.cache)
        small = M.init_cache(cfg, 1, max_len)
        self._slot_axes = [
            _slot_axis(b.shape, s.shape)
            for b, s in zip(big_leaves, jax.tree.leaves(small))]

        self._prefill = jax.jit(TL.make_prefill(cfg, policy=self.policy),
                                donate_argnums=(2,))
        self._step = jax.jit(TL.make_serve_step(cfg, policy=self.policy),
                             donate_argnums=(3,))
        self._write = jax.jit(self._write_slot, donate_argnums=(0,))

        # per-slot device-mirrored state (pos < 0 = inactive slot)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._pos = np.full((max_slots,), -1, np.int32)

        self.requests: List[Request] = []
        self._next_rid = 0
        self._t0: Optional[float] = None
        # aggregate counters
        self.prefill_tokens = 0
        self.prefill_time = 0.0
        self.decode_steps = 0
        self.decode_time = 0.0
        self.decode_slot_steps = 0     # sum of active slots over steps
        self.tokens_emitted = 0

    # -- cache slot copy ----------------------------------------------
    def _write_slot(self, cache, sub, slot):
        leaves = jax.tree.leaves(cache)
        subs = jax.tree.leaves(sub)
        out = []
        for leaf, s, ax in zip(leaves, subs, self._slot_axes):
            if ax is None:
                out.append(s.astype(leaf.dtype))
                continue
            start = [0] * leaf.ndim
            start[ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                leaf, s.astype(leaf.dtype), tuple(start)))
        return jax.tree.unflatten(self._treedef, out)

    # -- submission ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, arrival_time: float = 0.0,
               enc_frames=None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1
        assert max_new_tokens >= 1
        assert prompt.size + max_new_tokens <= self.max_len, \
            (prompt.size, max_new_tokens, self.max_len)
        if self.cfg.family == "encdec" and enc_frames is None:
            raise ValueError("encdec requests need enc_frames")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_time=arrival_time, enc_frames=enc_frames)
        self._next_rid += 1
        self.requests.append(req)
        self.scheduler.submit(req)
        return req

    # -- clock ---------------------------------------------------------
    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # -- admission (prefill path) ---------------------------------------
    def _admit(self, req: Request) -> None:
        slot = self.scheduler.admit(req)
        req.t_admitted = self._now()
        t0 = time.perf_counter()

        L = req.prompt_len
        chunk = self.prefill_chunk
        lb = L - (L % chunk) or L      # bucket down; short prompts exact
        batch: Dict[str, Any] = {"tokens": jnp.asarray(req.prompt[None, :lb])}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jnp.asarray(req.enc_frames[None])
        sub = M.init_cache(self.cfg, 1, self.max_len)
        logits, sub = self._prefill(self.params, batch, sub)
        for i in range(lb, L):         # remainder: one-token steps
            logits, sub = self._step(
                self.params, jnp.asarray(req.prompt[None, None, i]),
                jnp.int32(i), sub)
        self.cache = self._write(self.cache, sub, slot)

        row = np.asarray(logits)[0, -1, :self.cfg.vocab]
        tok = self.sampler(row)
        self.prefill_time += time.perf_counter() - t0
        self.prefill_tokens += L
        now = self._now()
        req.t_first_token = now
        req.generated.append(tok)
        self.tokens_emitted += 1
        if self._done(req, tok):
            self._finish(req, slot, now)
        else:
            self._pos[slot] = L
            self._tokens[slot, 0] = tok

    def _done(self, req: Request, tok: int) -> bool:
        return (req.n_generated >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))

    def _finish(self, req: Request, slot: int, now: float) -> None:
        self.scheduler.release(slot)
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0
        req.t_finished = now

    # -- decode --------------------------------------------------------
    def _decode_once(self) -> None:
        active = self.scheduler.active
        assert active
        t0 = time.perf_counter()
        logits, self.cache = self._step(
            self.params, jnp.asarray(self._tokens),
            jnp.asarray(self._pos), self.cache)
        rows = np.asarray(logits)[:, -1, :self.cfg.vocab]   # sync point
        self.decode_time += time.perf_counter() - t0
        self.decode_steps += 1
        self.decode_slot_steps += len(active)
        now = self._now()
        for slot in sorted(active):
            req = active[slot]
            tok = self.sampler(rows[slot])
            req.generated.append(tok)
            self.tokens_emitted += 1
            if self._done(req, tok):
                self._finish(req, slot, now)
            else:
                self._pos[slot] += 1
                self._tokens[slot, 0] = tok

    # -- driving -------------------------------------------------------
    def step(self) -> bool:
        """Admit every ready request, then run one decode step if any
        slot is active. Returns False when all work is drained."""
        while True:
            req = self.scheduler.next_admission(self._now())
            if req is None:
                break
            self._admit(req)
        if self.scheduler.n_active:
            self._decode_once()
        return self.scheduler.has_work()

    def run(self, *, idle_sleep: float = 1e-3) -> Dict[str, Any]:
        """Drive to completion; returns the stats report."""
        while self.scheduler.has_work():
            if not self.step():
                break
            if not self.scheduler.n_active:
                nxt = self.scheduler.next_arrival_time()
                if nxt is not None:
                    time.sleep(max(idle_sleep, min(nxt - self._now(), 0.05)))
        return self.report()

    # -- stats ----------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        done = [r for r in self.requests if r.status == FINISHED]
        lat = [r.latency for r in done]
        ttft = [r.ttft for r in done]
        n_emitted = sum(r.n_generated for r in self.requests)
        assert n_emitted == self.tokens_emitted, \
            (n_emitted, self.tokens_emitted)
        return {
            "n_requests": len(self.requests),
            "n_finished": len(done),
            "prefill_tokens": self.prefill_tokens,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_time,
                                                       1e-9),
            "decode_tokens": self.tokens_emitted - len(
                [r for r in self.requests if r.t_first_token is not None]),
            "decode_steps": self.decode_steps,
            "decode_tok_s": (self.decode_slot_steps
                             / max(self.decode_time, 1e-9)),
            "mean_occupancy": (self.decode_slot_steps
                               / max(self.decode_steps, 1)),
            "latency_p50_s": percentile(lat, 50),
            "latency_p95_s": percentile(lat, 95),
            "ttft_p50_s": percentile(ttft, 50),
            "ttft_p95_s": percentile(ttft, 95),
        }
