"""Continuous-batching serving engine over a fixed pool of cache slots.

Request lifecycle (one slot = one batch row of the jitted step):

        submit            slot free & arrived          len == max_new
    req ------> WAITING ----------------------> ACTIVE --------------> FINISHED
                          admit = prefill(1xL)         evict: pos[slot] = -1,
                          + copy into slot row         slot back in free pool

Every decode step runs ONE jitted serve_step over ALL slots with a
per-slot position vector `pos: (S,) int32` — heterogeneous requests
(different prompt lengths, admitted at different times) share the same
compiled program. Inactive slots carry pos = -1: the model masks their
cache writes and their logits are discarded, so idle rows cost FLOPs
but never correctness (the fixed batch shape is what keeps one XLA
executable serving the whole trace).

Admission prefills the prompt at batch size 1 into a fresh single-slot
cache, then copies that cache into the slot's row of the pooled cache.
Prompt lengths are bucketed down to a multiple of `prefill_chunk` for
the jitted prefill (bounding compile count under mixed-length traffic);
the 0..chunk-1 remainder tokens run through the same serve_step at
batch 1, so the admitted state is exactly what a full-length prefill
would have produced — tests/test_serving.py asserts token-exactness.

Family notes: attention caches copy per-slot KV rows; ssm/hybrid copy
recurrent state rows (their "position" is implicit in the state, the
pos vector only drives the attention members and bookkeeping). MoE is
served but not token-exact vs. an isolated run by construction: expert
capacity is contended by whichever tokens share the decode batch.

Paged mode (policy.kv_layout="paged"): the per-slot cache rows are
replaced by a fixed pool of KV pages plus a per-slot page table
(models.init_paged_cache + serving.kv_pool). Admission still prefills
into a dense batch-1 sub-cache, but the copy-out lands page by page
through the `_write_page` chokepoint — and pages whose content-hash
matches an already-resident prompt page are *shared* instead of
written. Decode writes go through `pool.prepare_write` first, which
turns a write into a shared page into a copy-on-write. Admission is
additionally gated on the pool guaranteeing the request's full write
range, so a decode step can never run out of pages mid-stream.
policy.quant_kv="int8" stores pages as int8 + per-(position, head)
scales, quantized at page write; the decode kernel dequantizes on its
f32 accumulator.

Fault tolerance (docs/ARCHITECTURE.md §Fault tolerance):

  * Deadlines + cancellation — waiters whose `deadline` passed are
    dropped (EXPIRED) before they ever burn a slot; `cancel(rid)`
    releases a waiting or mid-decode request immediately, refcount-safe
    against prefix-shared and mid-CoW KV pages.
  * Preemption — when the FCFS head cannot be admitted because the page
    pool is exhausted, the lowest-priority / youngest active slot is
    preempted instead of stalling the head: its private pages return to
    the pool (shared prefix pages survive via refcounts), the victim is
    requeued and later *resumed* by re-prefilling prompt + generated so
    far (token-identical continuation under greedy sampling). A
    per-request retry budget plus exponential resume backoff bound the
    churn.
  * Numeric guards — after every decode step a sentinel scans each
    active row's logits; a non-finite row quarantines ONLY that slot
    (terminal QUARANTINED status + diagnostic) while the rest of the
    batch keeps decoding. Repeated kernel-level faults (RuntimeError
    out of the jitted step) degrade the engine's policy to the `xla`
    registry backend with a once-per-process warning instead of
    crashing. (Step retry after a fault assumes the donated cache
    buffer survives — true on CPU/interpret where donation is a no-op;
    a real-device deployment would pair this with cache snapshots.)
  * Chaos harness — a `serving.faults.FaultInjector` drives all of the
    above at scripted step counts for deterministic tests and the
    `--chaos-*` serve CLI flags.
"""

from __future__ import annotations

import math
import time
import warnings
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as _pol
from repro.core import precision as _prec
from repro.distributed.fault_tolerance import StragglerDetector
from repro.models import model as M
from repro.serving.faults import FaultInjector
from repro.serving.kv_pool import KVPagePool, KVPoolExhausted
from repro.serving.request import (ACTIVE, CANCELLED, FINISHED, QUARANTINED,
                                   TERMINAL, WAITING, Request, percentile)
from repro.serving.sampler import Sampler
from repro.serving.scheduler import SlotScheduler
from repro.serving.spec import SpecDecoder
from repro.training import train_loop as TL

#: Default tokens per KV page in paged mode. 16 rows keeps a page's K
#: block a single sublane-aligned tile at head_dim 64-128 while keeping
#: internal fragmentation (half a page per request on average) small.
DEFAULT_PAGE_SIZE = 16

# Admission prefill buckets prompt lengths down to a multiple of this
# (remainder tokens run through one-token steps) to bound compile count.
DEFAULT_PREFILL_CHUNK = 8

# Degrading a faulting kernel backend to xla warns once per process.
_DEGRADE_WARNED = False


def _slot_axis(big_shape, small_shape, name: str = "cache leaf"):
    """Axis along which a cache leaf indexes slots: the axis where the
    max_slots-sized cache differs from the 1-slot cache. None = the leaf
    has no slot axis distinguishable (max_slots == 1: replace whole)."""
    diffs = [i for i, (a, b) in enumerate(zip(big_shape, small_shape))
             if a != b]
    if not diffs:
        return None
    if len(diffs) != 1:
        raise ValueError(
            f"cannot locate the slot axis of {name}: pooled shape "
            f"{tuple(big_shape)} differs from the 1-slot shape "
            f"{tuple(small_shape)} on axes {diffs}; per-slot admission "
            f"copies need exactly one differing (slot) axis")
    return diffs[0]


class ServingEngine:
    def __init__(self, cfg, params, *, max_slots: int, max_len: int,
                 sampler: Optional[Sampler] = None,
                 prefill_chunk: int = DEFAULT_PREFILL_CHUNK,
                 eos_id: Optional[int] = None, policy=None,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 kv_pool_pages: Optional[int] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 preempt_retry_budget: int = 2,
                 preempt_backoff: float = 0.02,
                 kernel_fault_threshold: int = 2,
                 max_step_retries: int = 2,
                 draft=None, spec_k: int = 4,
                 draft_policy=None,
                 draft_sampler: Optional[Sampler] = None):
        self.cfg = cfg
        # Execution policy for every jitted step this engine compiles —
        # captured once at construction (explicit arg > ambient default)
        # so a later ambient change can never retrace a live engine
        # under different kernels. The ONE exception is the engine's own
        # fault handler, which may degrade backend -> "xla" after
        # repeated kernel faults (see _degrade_to_xla).
        self.policy = _pol.resolve(policy)
        paged = self.policy.kv_layout == "paged"
        if paged and cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"kv_layout='paged' supports attention-cache families "
                f"(dense/moe/vlm), not {cfg.family!r}")
        if self.policy.quant_kv != "off" and not paged:
            raise ValueError(
                "quant_kv applies to KV pages; it requires "
                "kv_layout='paged' (dense caches stay full precision)")
        # quant="int8" policies quantize the dense weights ONCE here —
        # every jitted step then streams int8 weight tiles (the 2-4x
        # weight-traffic cut is the whole point of serving quantized);
        # embeddings and routers stay full precision (model.QUANT_EXCLUDE).
        if self.policy.quant == "int8":
            params = M.quantize_params(params)
        self.params = params
        self.max_slots = max_slots
        # chunked_attention requires kv lengths beyond attn_chunk to be
        # chunk multiples; max_len is trace-dependent, so round it up.
        # Paged mode additionally needs a whole number of pages so the
        # admission page copies never straddle the sub-cache end.
        a = cfg.attn_chunk
        if paged:
            m = math.lcm(a, page_size) if max_len > a else page_size
            if max_len % m:
                max_len += m - max_len % m
        elif max_len > a and max_len % a:
            max_len += a - max_len % a
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.eos_id = eos_id
        self.sampler = sampler or Sampler()
        self.scheduler = SlotScheduler(max_slots)
        self.injector = fault_injector
        self.preempt_retry_budget = preempt_retry_budget
        self.preempt_backoff = preempt_backoff
        self.kernel_fault_threshold = kernel_fault_threshold
        self.max_step_retries = max_step_retries
        self.straggler = StragglerDetector()

        self.page_size = page_size if paged else None
        self.pool: Optional[KVPagePool] = None
        if paged:
            pages_per_slot = max_len // page_size
            # Default pool = the dense layout's token capacity; prefix
            # sharing and early-exit requests then turn unused rows into
            # admission headroom instead of stranded slot tail.
            n_pages = (max_slots * pages_per_slot if kv_pool_pages is None
                       else kv_pool_pages)
            self.pool = KVPagePool(n_pages, page_size, max_slots,
                                   pages_per_slot)
            self.cache = M.init_paged_cache(
                cfg, n_pages, page_size, max_slots, pages_per_slot,
                quant_kv=self.policy.quant_kv)
            self._table_version = self.pool.version
            self._write_pg = jax.jit(self._write_page, donate_argnums=(0,))
            self._copy_pg = jax.jit(self._copy_page, donate_argnums=(0,))
        else:
            self.cache = M.init_cache(cfg, max_slots, max_len)
            flat, self._treedef = jax.tree_util.tree_flatten_with_path(
                self.cache)
            small = M.init_cache(cfg, 1, max_len)
            self._slot_axes = [
                _slot_axis(b.shape, s.shape,
                           name=jax.tree_util.keystr(path))
                for (path, b), s in zip(flat, jax.tree.leaves(small))]
            self._write = jax.jit(self._write_slot, donate_argnums=(0,))

        # -- speculative decoding (serving.spec) ------------------------
        # draft=(draft_cfg, draft_params) turns every decode step into a
        # draft round (spec_k cheap draft steps) plus ONE batched target
        # verification over all k+1 positions (model.verify_step); the
        # leftover/residual acceptance rule keeps the emitted stream
        # distribution-identical — token-exact under greedy sampling.
        self.spec: Optional[SpecDecoder] = None
        self.spec_k = spec_k
        self.spec_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        if draft is not None:
            if cfg.family not in ("dense", "moe", "vlm"):
                raise ValueError(
                    f"speculative decoding needs a verify-capable target "
                    f"(dense/moe/vlm), not {cfg.family!r}")
            if fault_injector is not None:
                raise ValueError(
                    "speculative decoding and the chaos injector are "
                    "mutually exclusive: the injector's step hooks assume "
                    "one token per slot per step")
            draft_cfg, draft_params = draft
            if draft_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab} != target vocab "
                    f"{cfg.vocab}: acceptance compares distributions "
                    f"over the same token space")
            # The draft runs under its own policy (default: the target's,
            # forced dense-KV — positional rollback needs no pages); a
            # different quant/backend keeps its tuning cache separate via
            # the policy fingerprint.
            dpol = _pol.resolve(draft_policy) if draft_policy is not None \
                else self.policy.replace(kv_layout="dense", quant_kv="off")
            self.spec = SpecDecoder(
                draft_cfg, draft_params, max_slots=max_slots,
                max_len=self.max_len, spec_k=spec_k, policy=dpol,
                sampler=draft_sampler, prefill_chunk=self.prefill_chunk)

        self._build_steps()

        # per-slot device-mirrored state (pos < 0 = inactive slot)
        self._tokens = np.zeros((max_slots, 1), np.int32)
        self._pos = np.full((max_slots,), -1, np.int32)

        self.requests: List[Request] = []
        self._next_rid = 0
        self._t0: Optional[float] = None
        # aggregate counters
        self.prefill_tokens = 0
        self.prefill_time = 0.0
        self.decode_steps = 0
        self.decode_time = 0.0
        self.decode_slot_steps = 0     # sum of active slots over steps
        self.tokens_emitted = 0
        self.peak_occupancy = 0
        self._step_times: List[float] = []
        # fault-tolerance counters
        self.expired = 0
        self.cancelled = 0
        self.preempted = 0             # preemption EVENTS (req may repeat)
        self.quarantined = 0
        self.kernel_faults = 0
        self.crashed_steps = 0         # steps that exhausted their retries
        self.degraded = False
        self._admissions = 0           # successful admissions (ordinal)

    def _build_steps(self) -> None:
        """(Re)compile the jitted prefill/serve steps under the current
        policy — called at construction and again by _degrade_to_xla."""
        self._prefill = jax.jit(TL.make_prefill(self.cfg,
                                                policy=self.policy),
                                donate_argnums=(2,))
        self._step = jax.jit(TL.make_serve_step(self.cfg,
                                                policy=self.policy),
                             donate_argnums=(3,))
        if self.spec is not None:
            self._vstep = jax.jit(TL.make_verify_step(self.cfg,
                                                      policy=self.policy),
                                  donate_argnums=(4,))

    # -- cache slot copy ----------------------------------------------
    def _write_slot(self, cache, sub, slot):
        leaves = jax.tree.leaves(cache)
        subs = jax.tree.leaves(sub)
        out = []
        for leaf, s, ax in zip(leaves, subs, self._slot_axes):
            if ax is None:
                out.append(s.astype(leaf.dtype))
                continue
            start = [0] * leaf.ndim
            start[ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                leaf, s.astype(leaf.dtype), tuple(start)))
        return jax.tree.unflatten(self._treedef, out)

    # -- page pool copies (paged layout) -------------------------------
    def _write_page(self, cache, sub, phys, start):
        """Copy `page_size` prefilled rows starting at `start` out of the
        dense batch-1 sub-cache into physical page `phys` of every
        layer's pool — THE admission-copy chokepoint for the paged
        layout (quantizing here when the policy asks for int8 pages)."""
        ps = self.page_size
        pages = dict(cache["pages"])
        z = jnp.int32(0)         # uniform index dtype (x64-safe)
        phys = jnp.int32(phys)
        for name in ("k", "v"):
            rows = jax.lax.dynamic_slice_in_dim(
                sub[name][:, 0], start, ps, axis=1)      # (L, ps, Hkv, Dh)
            if "ks" in pages:
                q, s = _prec.quantize_kv(rows)           # s: (L, ps, Hkv)
                pages[name] = jax.lax.dynamic_update_slice(
                    pages[name], q[:, None], (z, phys, z, z, z))
                pages[name + "s"] = jax.lax.dynamic_update_slice(
                    pages[name + "s"], s.transpose(0, 2, 1)[:, None],
                    (z, phys, z, z))
            else:
                pages[name] = jax.lax.dynamic_update_slice(
                    pages[name], rows[:, None].astype(pages[name].dtype),
                    (z, phys, z, z, z))
        return {"pages": pages, "table": cache["table"]}

    def _copy_page(self, cache, src, dst):
        """Device copy page src -> dst in every layer's pool (CoW)."""
        pages = {}
        z = jnp.int32(0)         # uniform index dtype (x64-safe)
        src, dst = jnp.int32(src), jnp.int32(dst)
        for name, leaf in cache["pages"].items():
            page = jax.lax.dynamic_slice(
                leaf, (z, src) + (z,) * (leaf.ndim - 2),
                (leaf.shape[0], 1) + leaf.shape[2:])
            pages[name] = jax.lax.dynamic_update_slice(
                leaf, page, (z, dst) + (z,) * (leaf.ndim - 2))
        return {"pages": pages, "table": cache["table"]}

    def _sync_table(self) -> None:
        """Mirror the host page table to the device cache when the pool
        has mutated it since the last jitted step."""
        if self.pool.version != self._table_version:
            self.cache = {"pages": self.cache["pages"],
                          "table": jnp.asarray(self.pool.table)}
            self._table_version = self.pool.version

    # -- submission ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, arrival_time: float = 0.0,
               deadline: Optional[float] = None, priority: int = 0,
               enc_frames=None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"request (prompt {prompt.size} + gen {max_new_tokens}) "
                f"exceeds the engine's max_len {self.max_len}")
        if self.cfg.family == "encdec" and enc_frames is None:
            raise ValueError("encdec requests need enc_frames")
        if self.pool is not None:
            # Infeasible-even-on-an-empty-pool requests are refused here,
            # cleanly, before they can wedge the FCFS queue; transient
            # fullness just defers admission (see step()).
            need = -(-(prompt.size + max_new_tokens) // self.page_size)
            if need > self.pool.n_pages:
                raise KVPoolExhausted(
                    f"request needs {need} KV pages (prompt {prompt.size} "
                    f"+ gen {max_new_tokens} @ page_size {self.page_size}) "
                    f"but the pool only has {self.pool.n_pages}")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_time=arrival_time, deadline=deadline,
                      priority=priority, enc_frames=enc_frames)
        self._next_rid += 1
        self.requests.append(req)
        self.scheduler.submit(req)
        return req

    # -- clock ---------------------------------------------------------
    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # -- admission (prefill path) ---------------------------------------
    def _copy_prefill(self, slot: int, sub, plan=None) -> None:
        """Admission-copy chokepoint for BOTH layouts. Dense copies the
        whole slot row (`_write_slot`); paged copies each freshly
        allocated prompt page through `_write_page` — pages the pool
        matched to an already-resident prefix are shared, not
        rewritten, which is where prefix admission gets cheap."""
        if self.pool is None:
            self.cache = self._write(self.cache, sub, slot)
            return
        for j, phys in plan.private:
            self.cache = self._write_pg(
                self.cache, sub, jnp.int32(phys),
                jnp.int32(j * self.page_size))

    def _admit(self, req: Request) -> None:
        """Prefill `req` into a free slot. A resumed (previously
        preempted) request re-prefills its FULL context — prompt plus
        everything generated before eviction — so decode continues
        exactly where it stopped (recompute-on-resume)."""
        slot = self.scheduler.admit(req)
        ctx = req.context_tokens()
        plan = None
        if self.pool is not None:
            plan = self.pool.admit_slot(slot, ctx, req.remaining_tokens)
        if req.t_admitted is None:
            req.t_admitted = self._now()
        self._admissions += 1
        t0 = time.perf_counter()

        L = len(ctx)
        chunk = self.prefill_chunk
        lb = L - (L % chunk) or L      # bucket down; short prompts exact
        batch: Dict[str, Any] = {"tokens": jnp.asarray(ctx[None, :lb])}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jnp.asarray(req.enc_frames[None])
        sub = M.init_cache(self.cfg, 1, self.max_len)
        logits, sub = self._prefill(self.params, batch, sub)
        for i in range(lb, L):         # remainder: one-token steps
            logits, sub = self._step(
                self.params, jnp.asarray(ctx[None, None, i]),
                jnp.int32(i), sub)
        self._copy_prefill(slot, sub, plan)

        row = np.asarray(logits)[0, -1, :self.cfg.vocab]
        self.prefill_time += time.perf_counter() - t0
        self.prefill_tokens += L
        now = self._now()
        if not np.isfinite(row).all():
            # same sentinel as decode: a poisoned prefill quarantines
            # this request only, never the engine
            req.error = "non-finite logits at admission prefill"
            self.quarantined += 1
            self._release(req, slot, QUARANTINED, now)
            return
        tok = self.sampler(row)
        if req.t_first_token is None:
            req.t_first_token = now
        req.generated.append(tok)
        self.tokens_emitted += 1
        if self._done(req, tok):
            self._finish(req, slot, now)
        else:
            self._pos[slot] = L
            self._tokens[slot, 0] = tok
            if self.spec is not None:
                # Fill the draft cache with the same context (rows
                # 0..L-1); the first draft round then feeds the pending
                # token at L. Resumes pass the fuller context through
                # here too (recompute-on-resume covers both caches).
                self.spec.admit(slot, ctx)

    def _done(self, req: Request, tok: int) -> bool:
        return (req.n_generated >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))

    # -- release / cancellation / preemption ----------------------------
    def _release(self, req: Request, slot: int, status: str,
                 now: float) -> None:
        """Free a slot into a terminal request state, returning its KV
        pages to the pool (refcount-safe: shared prefix pages and pages
        mid-CoW just drop one reference; survivors keep their bytes)."""
        self.scheduler.release(slot, status)
        if self.pool is not None:
            self.pool.release_slot(slot)
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0
        req.t_finished = now

    def _finish(self, req: Request, slot: int, now: float) -> None:
        self._release(req, slot, FINISHED, now)

    def cancel(self, rid: int) -> bool:
        """Cancel a request by id: a waiter leaves the queue, an active
        request gives up its slot and KV pages immediately. Returns
        False when the request is already terminal."""
        req = next((r for r in self.requests if r.rid == rid), None)
        if req is None:
            raise ValueError(f"unknown request id {rid}")
        if req.status in TERMINAL:
            return False
        now = self._now()
        if req.status == WAITING:
            self.scheduler.remove_waiting(req)
            req.status = CANCELLED
            req.t_finished = now
        elif req.status == ACTIVE:
            self._release(req, req.slot, CANCELLED, now)
        self.cancelled += 1
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Evict the request in `slot` back to the waiting queue,
        reclaiming its pages. Resume backoff doubles per eviction so a
        repeatedly-starved victim cannot thrash the admission loop."""
        req = self.scheduler.active[slot]
        backoff = self.preempt_backoff * (2 ** req.preemptions)
        req.preemptions += 1
        self.preempted += 1
        self.scheduler.preempt(slot, resume_at=self._now() + backoff)
        if self.pool is not None:
            self.pool.release_slot(slot)
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0

    def _preempt_for(self, head: Request) -> bool:
        """Pick and evict a victim so `head` can be admitted: the
        lowest-priority, then youngest (latest-admitted) active request
        that still has preemption-retry budget and is STRICTLY
        outranked by the head. Equal-priority contention defers FCFS
        instead (no churn; the pinned deferral semantics of a smooth
        trace are unchanged). Returns False when no victim exists."""
        cands = [(r.priority, -(r.t_admitted or 0.0), slot)
                 for slot, r in self.scheduler.active.items()
                 if r.preemptions < self.preempt_retry_budget
                 and r.priority < head.priority]
        if not cands:
            return False
        cands.sort()
        self._preempt_slot(cands[0][2])
        return True

    # -- numeric / kernel fault handling --------------------------------
    def _degrade_to_xla(self, err: BaseException) -> None:
        global _DEGRADE_WARNED
        self.policy = self.policy.replace(backend="xla")
        self._build_steps()
        self.degraded = True
        if not _DEGRADE_WARNED:
            _DEGRADE_WARNED = True
            warnings.warn(
                f"serving engine degraded to the 'xla' registry backend "
                f"after {self.kernel_faults} kernel fault(s) (last: "
                f"{err!r}); latency may regress but the trace continues",
                RuntimeWarning, stacklevel=2)

    def _run_step(self, step_idx: int):
        """One guarded jitted decode step: kernel-level faults are
        retried, and once they repeat past `kernel_fault_threshold` the
        engine rebuilds its steps on the xla backend instead of
        crashing. A step that exhausts its retries counts as crashed and
        re-raises."""
        tokens = jnp.asarray(self._tokens)
        pos = jnp.asarray(self._pos)
        attempts = 0
        while True:
            try:
                if self.injector is not None:
                    self.injector.before_kernel(step_idx)
                return self._step(self.params, tokens, pos, self.cache)
            except RuntimeError as e:   # kernel faults, incl. simulated
                attempts += 1
                self.kernel_faults += 1
                if attempts > self.max_step_retries:
                    self.crashed_steps += 1
                    raise
                if (self.kernel_faults >= self.kernel_fault_threshold
                        and not self.degraded
                        and self.policy.backend != "xla"):
                    self._degrade_to_xla(e)

    def _poison_slot_cache(self, slot: int) -> None:
        """Chaos-harness hook: NaN a cache region PRIVATE to `slot` so
        the fault surfaces through real attention math. Paged mode
        poisons the slot's current write page (made private by
        prepare_write just before this runs — a shared page is never
        touched, pinning the sharer-survives contract); dense mode
        poisons the slot's row of every float cache leaf."""
        if self.pool is not None:
            j = int(self._pos[slot]) // self.page_size
            phys = int(self.pool.table[slot, j])
            pages = dict(self.cache["pages"])
            for name in ("k", "v"):
                # int8 pages cannot hold a NaN; poison the scales
                target = name + "s" if name + "s" in pages else name
                pages[target] = pages[target].at[:, phys].set(jnp.nan)
            self.cache = {"pages": pages, "table": self.cache["table"]}
            return
        leaves = jax.tree.leaves(self.cache)
        out = []
        for leaf, ax in zip(leaves, self._slot_axes):
            if ax is None or not jnp.issubdtype(leaf.dtype, jnp.floating):
                out.append(leaf)
                continue
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            out.append(leaf.at[tuple(idx)].set(jnp.nan))
        self.cache = jax.tree.unflatten(self._treedef, out)

    # -- decode --------------------------------------------------------
    def _decode_once(self) -> None:
        active = self.scheduler.active
        if not active:
            raise ValueError("decode step with no active slots")
        step_idx = self.decode_steps
        if self.pool is not None:
            # Make every slot's write position privately owned BEFORE
            # the jitted step scatters into it: a write into a shared
            # page becomes a device page copy (CoW), a write past the
            # mapped prefix allocates from the reservation made at
            # admission (so this can never fail mid-stream).
            for slot in active:
                w = self.pool.prepare_write(slot, int(self._pos[slot]))
                if w is not None and w.kind == "cow":
                    self.cache = self._copy_pg(
                        self.cache, jnp.int32(w.src), jnp.int32(w.dst))
            self._sync_table()
        if self.injector is not None:
            for slot in self.injector.corrupt_slots(step_idx, tuple(active)):
                self._poison_slot_cache(slot)
        t0 = time.perf_counter()
        logits, self.cache = self._run_step(step_idx)
        rows = np.asarray(logits)[:, -1, :self.cfg.vocab]   # sync point
        dt = time.perf_counter() - t0
        self.decode_time += dt
        self._step_times.append(dt)
        self.straggler.observe(step_idx, dt)
        self.decode_steps += 1
        self.decode_slot_steps += len(active)
        self.peak_occupancy = max(self.peak_occupancy, len(active))
        if self.injector is not None:
            rows = self.injector.poison_rows(step_idx, rows, tuple(active))
        now = self._now()
        for slot in sorted(active):
            req = active[slot]
            if not np.isfinite(rows[slot]).all():
                # quarantine ONLY the poisoned slot; co-scheduled rows
                # are untouched (their logits never mix across slots)
                req.error = f"non-finite logits at decode step {step_idx}"
                self.quarantined += 1
                self._release(req, slot, QUARANTINED, now)
                continue
            tok = self.sampler(rows[slot])
            req.generated.append(tok)
            self.tokens_emitted += 1
            if self._done(req, tok):
                self._finish(req, slot, now)
            else:
                self._pos[slot] += 1
                self._tokens[slot, 0] = tok

    # -- speculative decode (draft round + ONE batched verification) ----
    def _spec_decode_once(self) -> None:
        """One speculative round: spec_k draft steps propose tokens for
        every active slot, then the TARGET model scores all k+1
        positions (pending + drafts) in ONE prefill-shaped verify_step —
        batched verification is the whole subsystem's point; the per-
        round target cost is one multi-token forward, never k decode
        steps. Acceptance (sampler.speculative_accept) emits 1..k+1
        tokens per slot; the target/draft caches need no rollback work
        because rollback is positional (see serving/spec.py docstring):
        rows past each slot's new pending position are stale but masked,
        and the next round overwrites them before they could be read."""
        active = self.scheduler.active
        if not active:
            raise ValueError("decode step with no active slots")
        step_idx = self.decode_steps
        k = self.spec_k
        k_vec = np.zeros(self.max_slots, np.int32)
        for slot, req in active.items():
            # a slot about to hit its budget proposes fewer drafts —
            # tokens past max_new would be drafted only to be dropped
            k_vec[slot] = min(k, req.remaining_tokens - 1)
        t0 = time.perf_counter()
        drafts, qprobs = self.spec.draft_round(self._tokens, self._pos,
                                               k_vec)
        vtokens = np.zeros((self.max_slots, k + 1), np.int32)
        vtokens[:, 0] = self._tokens[:, 0]
        vtokens[:, 1:] = drafts
        n_tok = np.where(self._pos >= 0, k_vec + 1, 0).astype(np.int32)
        if self.pool is not None:
            # every position the verify scatter may write must be
            # privately owned first; the admission reservation covers
            # the full range (max write pos + k_vec stays short of the
            # reserved last page), so this never fails mid-stream.
            for slot in active:
                p0 = int(self._pos[slot])
                ps = self.page_size
                for j in range(p0 // ps, (p0 + int(n_tok[slot]) - 1) // ps + 1):
                    w = self.pool.prepare_write(slot, j * ps)
                    if w is not None and w.kind == "cow":
                        self.cache = self._copy_pg(
                            self.cache, jnp.int32(w.src), jnp.int32(w.dst))
            self._sync_table()
        logits, self.cache = self._vstep(
            self.params, jnp.asarray(vtokens), jnp.asarray(self._pos),
            jnp.asarray(n_tok), self.cache)
        rows = np.asarray(logits)[:, :, :self.cfg.vocab]    # sync point
        dt = time.perf_counter() - t0
        self.decode_time += dt
        self._step_times.append(dt)
        self.straggler.observe(step_idx, dt)
        self.decode_steps += 1
        self.spec_rounds += 1
        self.decode_slot_steps += len(active)
        self.peak_occupancy = max(self.peak_occupancy, len(active))
        now = self._now()
        for slot in sorted(active):
            req = active[slot]
            nt = int(n_tok[slot])
            if not np.isfinite(rows[slot, :nt]).all():
                req.error = f"non-finite logits at decode step {step_idx}"
                self.quarantined += 1
                self._release(req, slot, QUARANTINED, now)
                continue
            kk = nt - 1
            emitted, n_acc = self.sampler.speculative_accept(
                rows[slot, :nt], drafts[slot, :kk],
                None if qprobs is None else qprobs[slot, :kk])
            req.draft_proposed += kk
            req.draft_accepted += n_acc
            self.spec_proposed += kk
            self.spec_accepted += n_acc
            n_cons = 0
            finished = False
            for tok in emitted:
                req.generated.append(tok)
                self.tokens_emitted += 1
                n_cons += 1
                if self._done(req, tok):   # eos truncates mid-round
                    finished = True
                    break
            if finished:
                self._finish(req, slot, now)
            else:
                self._pos[slot] += n_cons
                self._tokens[slot, 0] = emitted[n_cons - 1]

    # -- driving -------------------------------------------------------
    def step(self) -> bool:
        """Drop expired waiters, admit every ready request (preempting
        for a pool-starved FCFS head when a victim exists), then run one
        decode step if any slot is active. Returns False when all work
        is drained."""
        while True:
            now = self._now()
            for req in self.scheduler.drop_expired(now):
                req.t_finished = now
                self.expired += 1
            req = self.scheduler.next_admission(now)
            if req is None:
                break
            if self.pool is not None:
                denied = (self.injector is not None
                          and self.injector.deny_admission(self._admissions))
                ok = not denied and self.pool.can_admit(
                    req.context_tokens(), req.remaining_tokens)
                while not ok and self._preempt_for(req):
                    ok = self.pool.can_admit(req.context_tokens(),
                                             req.remaining_tokens)
                if not ok:
                    break   # head waits for pages to free
            self._admit(req)
        if self.scheduler.n_active:
            if self.spec is not None:
                self._spec_decode_once()
            else:
                self._decode_once()
        return self.scheduler.has_work()

    def run(self, *, idle_sleep: float = 1e-3) -> Dict[str, Any]:
        """Drive to completion; returns the stats report."""
        while self.scheduler.has_work():
            if not self.step():
                break
            if not self.scheduler.n_active:
                nxt = self.scheduler.next_arrival_time()
                if nxt is not None:
                    time.sleep(max(idle_sleep, min(nxt - self._now(), 0.05)))
        return self.report()

    # -- stats ----------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        done = [r for r in self.requests if r.status == FINISHED]
        lat = [r.latency for r in done]
        ttft = [r.ttft for r in done]
        n_emitted = sum(r.n_generated for r in self.requests)
        assert n_emitted == self.tokens_emitted, \
            (n_emitted, self.tokens_emitted)
        waits = [r.t_admitted - r.arrival_time for r in self.requests
                 if r.t_admitted is not None]
        # goodput: only tokens of requests that FINISHED (and met their
        # deadline, if they had one) were worth emitting; everything a
        # cancelled / expired / quarantined / late request decoded is
        # wasted work. (Preemption waste is re-PREFILL compute and so
        # shows up in prefill_tokens, not here — no token is emitted
        # twice.)
        useful = sum(r.n_generated for r in done
                     if r.missed_deadline is not True)
        deadlined = [r for r in self.requests
                     if r.deadline is not None and r.status in TERMINAL]
        missed = [r for r in deadlined if r.missed_deadline]
        decode_tokens = self.tokens_emitted - len(
            [r for r in self.requests if r.t_first_token is not None])
        out = {
            "n_requests": len(self.requests),
            "n_finished": len(done),
            "prefill_tokens": self.prefill_tokens,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_time,
                                                       1e-9),
            "decode_tokens": decode_tokens,
            "decode_steps": self.decode_steps,
            "decode_tok_s": (self.decode_slot_steps
                             / max(self.decode_time, 1e-9)),
            "mean_occupancy": (self.decode_slot_steps
                               / max(self.decode_steps, 1)),
            "latency_p50_s": percentile(lat, 50),
            "latency_p95_s": percentile(lat, 95),
            "ttft_p50_s": percentile(ttft, 50),
            "ttft_p95_s": percentile(ttft, 95),
            "peak_occupancy": self.peak_occupancy,
            "decode_step_p50_s": percentile(self._step_times, 50),
            "decode_step_p99_s": percentile(self._step_times, 99),
            "admission_wait_p50_s": percentile(waits, 50),
            "admission_wait_p99_s": percentile(waits, 99),
            # fault tolerance
            "expired": self.expired,
            "cancelled": self.cancelled,
            "preempted": self.preempted,
            "quarantined": self.quarantined,
            "kernel_faults": self.kernel_faults,
            "crashed_steps": self.crashed_steps,
            "degraded": self.degraded,
            "straggler_steps": len(self.straggler.flagged),
            "useful_tokens": useful,
            "goodput": useful / max(self.tokens_emitted, 1),
            "deadline_miss_rate": (len(missed) / len(deadlined)
                                   if deadlined else float("nan")),
            # tokens emitted per slot-step: exactly 1.0 for plain
            # decode (minus quarantines), > 1.0 when speculation pays
            "tokens_per_step": decode_tokens / max(self.decode_slot_steps,
                                                   1),
        }
        if self.spec is not None:
            out["spec_rounds"] = self.spec_rounds
            out["spec_proposed"] = self.spec_proposed
            out["spec_accepted"] = self.spec_accepted
            out["spec_acceptance_rate"] = (self.spec_accepted
                                           / max(self.spec_proposed, 1))
            out["draft_time_s"] = self.spec.draft_time
            out["draft_prefill_time_s"] = self.spec.prefill_time
        if self.injector is not None:
            out["faults_injected"] = self.injector.report()
        if self.pool is not None:
            out["kv_pool"] = self.pool.report()
        return out
