"""Continuous-batching serving subsystem.

    engine.py     request lifecycle admit -> prefill -> decode -> evict
                  over a fixed pool of cache slots
    scheduler.py  slot allocation + FCFS admission
    sampler.py    greedy / temperature / top-k token selection
    request.py    dataclasses + per-request stats
    workload.py   synthetic mixed-length arrival-trace generator

See docs/ARCHITECTURE.md §Serving engine for the layer map.
"""

from repro.serving.engine import DEFAULT_PREFILL_CHUNK, ServingEngine
from repro.serving.request import Request, percentile
from repro.serving.sampler import Sampler, SamplerConfig, make_sampler
from repro.serving.scheduler import SlotScheduler
from repro.serving.workload import synthetic_trace

__all__ = [
    "DEFAULT_PREFILL_CHUNK", "ServingEngine", "Request", "percentile",
    "Sampler", "SamplerConfig", "make_sampler", "SlotScheduler",
    "synthetic_trace",
]
