"""Continuous-batching serving subsystem.

    engine.py     request lifecycle admit -> prefill -> decode -> evict
                  over a fixed pool of cache slots, with deadline /
                  cancel / preempt / quarantine fault handling
    scheduler.py  slot allocation + FCFS admission over eligible
                  waiters (arrival + preemption-resume backoff),
                  deadline expiry, preemption requeue
    kv_pool.py    paged KV layout: page pool + per-slot page tables,
                  content-hashed prefix sharing, copy-on-write
    sampler.py    greedy / temperature / top-k token selection, plus
                  the speculative leftover/residual acceptance rule
    spec.py       speculative decoding draft side: per-slot draft KV
                  state, masked draft rounds, positional rollback
    request.py    dataclasses + per-request stats
    workload.py   synthetic arrival-trace scenario registry (mixed,
                  prefix_heavy, bursty compound-Poisson, long_context;
                  optional deadlines, priorities, bursty arrivals)
    faults.py     deterministic chaos injector (NaN rows, page
                  corruption, kernel faults, slow steps, forced pool
                  exhaustion) scripted by step counts

See docs/ARCHITECTURE.md §Serving engine, §Paged KV cache, §Fault
tolerance and §Speculative decoding for the layer maps.
"""

from repro.serving.engine import (DEFAULT_PAGE_SIZE, DEFAULT_PREFILL_CHUNK,
                                  ServingEngine)
from repro.serving.faults import FaultInjector, SimulatedKernelFault
from repro.serving.kv_pool import (AdmitPlan, KVPagePool, KVPoolExhausted,
                                   PageWrite)
from repro.serving.request import Request, percentile
from repro.serving.sampler import (Sampler, SamplerConfig, make_sampler,
                                   residual_distribution)
from repro.serving.scheduler import SlotScheduler
from repro.serving.spec import SpecDecoder
from repro.serving.workload import (TRACES, TraceItem, bursty_trace,
                                    long_context_trace, make_trace,
                                    prefix_heavy_trace, synthetic_trace)

__all__ = [
    "AdmitPlan", "DEFAULT_PAGE_SIZE", "DEFAULT_PREFILL_CHUNK",
    "FaultInjector", "KVPagePool", "KVPoolExhausted", "PageWrite",
    "ServingEngine", "SimulatedKernelFault", "SpecDecoder",
    "Request", "percentile",
    "Sampler", "SamplerConfig", "make_sampler", "residual_distribution",
    "SlotScheduler",
    "TRACES", "TraceItem", "bursty_trace", "long_context_trace",
    "make_trace", "prefix_heavy_trace", "synthetic_trace",
]
