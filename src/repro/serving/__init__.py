"""Continuous-batching serving subsystem.

    engine.py     request lifecycle admit -> prefill -> decode -> evict
                  over a fixed pool of cache slots
    scheduler.py  slot allocation + FCFS admission
    kv_pool.py    paged KV layout: page pool + per-slot page tables,
                  content-hashed prefix sharing, copy-on-write
    sampler.py    greedy / temperature / top-k token selection
    request.py    dataclasses + per-request stats
    workload.py   synthetic arrival-trace generators (mixed-length +
                  prefix-heavy chat)

See docs/ARCHITECTURE.md §Serving engine and §Paged KV cache for the
layer maps.
"""

from repro.serving.engine import (DEFAULT_PAGE_SIZE, DEFAULT_PREFILL_CHUNK,
                                  ServingEngine)
from repro.serving.kv_pool import (AdmitPlan, KVPagePool, KVPoolExhausted,
                                   PageWrite)
from repro.serving.request import Request, percentile
from repro.serving.sampler import Sampler, SamplerConfig, make_sampler
from repro.serving.scheduler import SlotScheduler
from repro.serving.workload import prefix_heavy_trace, synthetic_trace

__all__ = [
    "AdmitPlan", "DEFAULT_PAGE_SIZE", "DEFAULT_PREFILL_CHUNK",
    "KVPagePool", "KVPoolExhausted", "PageWrite", "ServingEngine",
    "Request", "percentile",
    "Sampler", "SamplerConfig", "make_sampler", "SlotScheduler",
    "prefix_heavy_trace", "synthetic_trace",
]
