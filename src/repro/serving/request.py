"""Request lifecycle dataclasses + per-request stats.

A request moves WAITING -> ACTIVE -> FINISHED on the happy path. While
ACTIVE it owns one cache slot (a batch row of the engine's KV/state
cache); on finish the slot is released and the next waiting request is
admitted into it — that hand-off, happening while other slots keep
decoding, is what makes the batching "continuous".

Fault-tolerant serving adds terminal and transient edges (see
docs/ARCHITECTURE.md §Fault tolerance):

  * EXPIRED      — a waiter whose `deadline` passed before admission is
                   dropped by the scheduler instead of wasting a slot.
  * CANCELLED    — `engine.cancel(rid)` released the request (waiting or
                   mid-decode); its slot and KV pages are reclaimed.
  * QUARANTINED  — the decode-step numeric sentinel saw non-finite
                   logits on this request's row and terminated it with a
                   diagnostic (`error`), leaving the rest of the batch
                   decoding.
  * preemption   — ACTIVE -> WAITING: the engine reclaimed the slot's
                   private KV pages for a starving FCFS head; on resume
                   the full context (prompt + generated so far) is
                   re-prefilled and generation continues where it left
                   off, token-identical under greedy sampling.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

WAITING = "waiting"
ACTIVE = "active"
FINISHED = "finished"
EXPIRED = "expired"
CANCELLED = "cancelled"
QUARANTINED = "quarantined"

#: States a request can end in (slot and pages released for good).
TERMINAL = (FINISHED, EXPIRED, CANCELLED, QUARANTINED)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_time: float = 0.0          # seconds on the engine clock
    deadline: Optional[float] = None   # absolute engine-clock seconds
    priority: int = 0                  # higher = more important
    enc_frames: Optional[np.ndarray] = None   # encdec: (enc_ctx, d_model)

    # engine-owned state
    status: str = WAITING
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    preemptions: int = 0               # times this request lost its slot
    resume_at: float = 0.0             # earliest re-admission (backoff)
    error: Optional[str] = None        # diagnostic for quarantined/failed
    draft_proposed: int = 0            # speculative tokens proposed for
    draft_accepted: int = 0            # ... / accepted on this request

    @property
    def acceptance_rate(self) -> Optional[float]:
        """Fraction of draft proposals the target accepted (None when
        the engine ran without speculation)."""
        if self.draft_proposed == 0:
            return None
        return self.draft_accepted / self.draft_proposed

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, got "
                f"{self.max_new_tokens}")
        if self.deadline is not None and self.deadline < self.arrival_time:
            raise ValueError(
                f"request {self.rid}: deadline {self.deadline} precedes "
                f"arrival_time {self.arrival_time}")

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def remaining_tokens(self) -> int:
        """Tokens still to generate (less than max_new_tokens after a
        preemption resumed a partially-decoded request)."""
        return max(0, self.max_new_tokens - self.n_generated)

    def context_tokens(self) -> np.ndarray:
        """Prompt plus everything generated so far — what a resume
        re-prefills so decode continues exactly where it stopped."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def ttft(self) -> Optional[float]:
        """Time-to-first-token (admission prefill completes)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_time

    @property
    def latency(self) -> Optional[float]:
        if self.t_finished is None:
            return None
        return self.t_finished - self.arrival_time

    @property
    def missed_deadline(self) -> Optional[bool]:
        """True/False once terminal and a deadline was set, else None."""
        if self.deadline is None or self.status not in TERMINAL:
            return None
        if self.status != FINISHED:
            return True
        return self.t_finished > self.deadline


def percentile(values, q: float) -> float:
    vals = [v for v in values if v is not None]
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals, np.float64), q))
