"""Request lifecycle dataclasses + per-request stats.

A request moves WAITING -> ACTIVE -> FINISHED. While ACTIVE it owns one
cache slot (a batch row of the engine's KV/state cache); on finish the
slot is released and the next waiting request is admitted into it —
that hand-off, happening while other slots keep decoding, is what makes
the batching "continuous".
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

WAITING = "waiting"
ACTIVE = "active"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_time: float = 0.0          # seconds on the engine clock
    enc_frames: Optional[np.ndarray] = None   # encdec: (enc_ctx, d_model)

    # engine-owned state
    status: str = WAITING
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def ttft(self) -> Optional[float]:
        """Time-to-first-token (admission prefill completes)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_time

    @property
    def latency(self) -> Optional[float]:
        if self.t_finished is None:
            return None
        return self.t_finished - self.arrival_time


def percentile(values, q: float) -> float:
    vals = [v for v in values if v is not None]
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals, np.float64), q))
