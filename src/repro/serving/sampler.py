"""Token selection for the serving engine: greedy / temperature / top-k.

Sampling runs host-side on the (vocab,) logits row of each active slot
— at decode batch sizes the device step is the bottleneck, and host
sampling keeps the jitted serve_step purely functional (same lowering
as the dry-run).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"           # greedy | temperature
    temperature: float = 1.0
    top_k: int = 0                 # 0 = full vocab (temperature mode)

    def __post_init__(self):
        if self.kind not in ("greedy", "temperature"):
            raise ValueError(self.kind)
        if self.kind == "temperature" and self.temperature <= 0:
            raise ValueError("temperature must be > 0 (use kind='greedy')")


class Sampler:
    """Stateful sampler: one np.random.Generator shared by all slots so
    a fixed seed gives a reproducible trace."""

    def __init__(self, config: SamplerConfig | None = None, seed: int = 0):
        self.config = config or SamplerConfig()
        self._rng = np.random.default_rng(seed)

    def __call__(self, logits: np.ndarray) -> int:
        """logits: (vocab,) float32 -> token id."""
        c = self.config
        if c.kind == "greedy":
            return int(np.argmax(logits))
        z = logits.astype(np.float64) / c.temperature
        k = min(c.top_k, z.size)       # top_k >= vocab = full vocab
        if k:
            kth = np.partition(z, -k)[-k]
            z = np.where(z >= kth, z, -np.inf)
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))


def make_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0) -> Sampler:
    return Sampler(SamplerConfig(kind=kind, temperature=temperature,
                                 top_k=top_k), seed=seed)
