"""Token selection for the serving engine: greedy / temperature / top-k.

Sampling runs host-side on the (vocab,) logits row of each active slot
— at decode batch sizes the device step is the bottleneck, and host
sampling keeps the jitted serve_step purely functional (same lowering
as the dry-run).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"           # greedy | temperature
    temperature: float = 1.0
    top_k: int = 0                 # 0 = full vocab (temperature mode)

    def __post_init__(self):
        if self.kind not in ("greedy", "temperature"):
            raise ValueError(self.kind)
        if self.kind == "temperature" and self.temperature <= 0:
            raise ValueError("temperature must be > 0 (use kind='greedy')")


class Sampler:
    """Stateful sampler: one np.random.Generator shared by all slots so
    a fixed seed gives a reproducible trace."""

    def __init__(self, config: SamplerConfig | None = None, seed: int = 0):
        self.config = config or SamplerConfig()
        self._rng = np.random.default_rng(seed)

    def __call__(self, logits: np.ndarray) -> int:
        """logits: (vocab,) float32 -> token id."""
        c = self.config
        if c.kind == "greedy":
            return int(np.argmax(logits))
        z = logits.astype(np.float64) / c.temperature
        k = min(c.top_k, z.size)       # top_k >= vocab = full vocab
        if k:
            kth = np.partition(z, -k)[-k]
            z = np.where(z >= kth, z, -np.inf)
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # -- speculative decoding primitives --------------------------------

    def probs(self, logits: np.ndarray) -> np.ndarray:
        """The (vocab,) distribution this sampler draws from at `logits`
        — greedy is the one-hot delta at the argmax, temperature is the
        (optionally top-k-truncated) softmax. This is the p (target) /
        q (draft) of the speculative acceptance rule."""
        c = self.config
        if c.kind == "greedy":
            p = np.zeros(logits.shape[-1], np.float64)
            p[int(np.argmax(logits))] = 1.0
            return p
        z = logits.astype(np.float64) / c.temperature
        k = min(c.top_k, z.size)
        if k:
            kth = np.partition(z, -k)[-k]
            z = np.where(z >= kth, z, -np.inf)
        z -= z.max()
        p = np.exp(z)
        return p / p.sum()

    def sample_from(self, p: np.ndarray) -> int:
        """Draw from an explicit distribution with this sampler's rng
        stream (used for the residual draw on rejection)."""
        if self.config.kind == "greedy":
            return int(np.argmax(p))
        return int(self._rng.choice(len(p), p=p))

    def speculative_accept(self, target_logits: np.ndarray,
                           draft_tokens, draft_probs=None):
        """Leftover/residual acceptance rule (Leviathan et al.):
        for each draft token x_j with draft distribution q_j and target
        distribution p_j, accept with probability min(1, p_j(x_j) /
        q_j(x_j)); on the first rejection emit a draw from
        norm(max(p_j - q_j, 0)) and stop; on full acceptance emit a
        bonus draw from the final target row. The emitted stream is
        distribution-identical to sampling the target alone — and for
        greedy (q = delta at the draft argmax, p = delta at the target
        argmax) it degenerates to token-exact argmax agreement.

        target_logits: (k+1, vocab) — row j scores draft token j, row k
        is the bonus row. draft_tokens: (k,) proposed ids. draft_probs:
        (k, vocab) distributions the DRAFT sampler drew from (its
        .probs of each draft logits row), or None when the draft
        proposes deterministically (greedy draft): q_j is then the
        delta at x_j and acceptance is min(1, p_j(x_j)).

        Returns (emitted tokens list — len in [1, k+1], n_accepted).
        """
        k = len(draft_tokens)
        assert target_logits.shape[0] == k + 1, target_logits.shape
        emitted: list[int] = []
        for j in range(k):
            x = int(draft_tokens[j])
            if self.config.kind == "greedy":
                best = int(np.argmax(target_logits[j]))
                emitted.append(x if x == best else best)
                if x != best:
                    return emitted, j
                continue
            p = self.probs(target_logits[j])
            if draft_probs is None:
                q_x = 1.0
                q = np.zeros_like(p)
                q[x] = 1.0
            else:
                q = np.asarray(draft_probs[j], np.float64)
                q_x = q[x]
            if q_x > 0 and self._rng.random() * q_x <= p[x]:
                emitted.append(x)
                continue
            emitted.append(self.sample_from(residual_distribution(p, q)))
            return emitted, j
        emitted.append(self.sample_from(self.probs(target_logits[k])))
        return emitted, k


def residual_distribution(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """norm(max(p - q, 0)) — the rejection-path draw of the speculative
    acceptance rule; falls back to p when the residual has no mass
    (q covers p pointwise, possible only up to float error)."""
    res = np.maximum(np.asarray(p, np.float64) - np.asarray(q, np.float64),
                     0.0)
    mass = res.sum()
    return res / mass if mass > 0 else np.asarray(p, np.float64)


def make_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0) -> Sampler:
    return Sampler(SamplerConfig(kind=kind, temperature=temperature,
                                 top_k=top_k), seed=seed)
