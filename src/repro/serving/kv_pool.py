"""Host-side page pool for the paged KV cache.

The serving engine's dense cache spends ``max_slots * max_len`` rows of
HBM whether or not a slot ever reaches ``max_len``; this module replaces
that with a fixed pool of ``(page_size, heads, head_dim)`` K/V pages and
a per-slot page table, so capacity is bounded by *tokens actually
resident* rather than by the worst case. Two mechanisms pay for the
indirection:

* **Prefix sharing.** Prompt pages are content-hashed at admission with
  a prefix-chained digest (page j's digest folds in page j-1's), so two
  requests sharing a system prompt map the same physical pages and pay
  for them once. The final *partial* prompt page participates too — its
  digest folds in the token count, so "same 40-token prefix" matches
  while "same 32 tokens then diverges" does not.
* **Copy-on-write.** A decode write into a page with refcount > 1 first
  copies it to a fresh page and retargets the writer's table entry; the
  sharers keep the original bytes. Stale generated-token rows inherited
  by a CoW copy are harmless: writers fill positions contiguously from
  their prompt length, and the decode kernel masks ``k_pos <= pos``, so
  every stale row is overwritten before it is ever attended to.

The pool is pure host bookkeeping (numpy table, refcounts, free list);
the engine owns the device arrays and performs the copies the pool's
directives describe. Accounting is conservative: admission reserves a
page for every position the request may ever write into a page it does
not privately own, so ``prepare_write`` can never fail mid-stream — a
request is either refused up front (``KVPoolExhausted``) or guaranteed
to finish.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

__all__ = ["AdmitPlan", "KVPagePool", "KVPoolExhausted", "PageWrite"]


class KVPoolExhausted(RuntimeError):
    """Admission refused: the pool cannot guarantee the request's full
    write range. Raised at admission only — never mid-decode."""


@dataclass(frozen=True)
class AdmitPlan:
    """What admission decided for one slot: which logical prompt pages
    landed on shared physical pages (already populated — the engine must
    NOT write them) and which were freshly allocated (the engine fills
    them from its prefill)."""

    slot: int
    shared: tuple[tuple[int, int], ...]    # (logical_page, phys_page)
    private: tuple[tuple[int, int], ...]   # (logical_page, phys_page)


@dataclass(frozen=True)
class PageWrite:
    """Directive from ``prepare_write``: before writing position ``pos``
    the engine must either zero-init a fresh page (``kind="alloc"``) or
    device-copy ``src`` into ``dst`` (``kind="cow"``). The table row is
    already retargeted when this is returned."""

    kind: str                              # "alloc" | "cow"
    logical: int
    dst: int
    src: int | None = None


@dataclass
class _Stats:
    admitted: int = 0
    refused: int = 0
    shared_page_hits: int = 0
    cow_copies: int = 0
    pages_allocated: int = 0
    peak_resident: int = 0
    peak_sharing: float = 1.0


class KVPagePool:
    """Bookkeeping for a fixed pool of KV pages shared by all slots.

    Parameters
    ----------
    n_pages:        physical pool size (per layer; the table is shared
                    across layers, so one logical page is the same
                    physical index in every layer's pool).
    page_size:      tokens per page.
    max_slots:      page-table rows.
    pages_per_slot: page-table width — ``max_len // page_size``.
    """

    def __init__(self, n_pages: int, page_size: int, max_slots: int,
                 pages_per_slot: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.max_slots = int(max_slots)
        self.pages_per_slot = int(pages_per_slot)
        # -1 = unmapped; the kernel's index map clamps to page 0 and the
        # causal mask hides whatever it streams.
        self.table = np.full((max_slots, pages_per_slot), -1, np.int32)
        self.refcount = np.zeros(n_pages, np.int64)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        # digest -> phys page for shareable (prompt-only) pages, plus
        # the reverse map so a freed page drops out of the registry.
        self._by_hash: dict[bytes, int] = {}
        self._hash_of: dict[int, bytes] = {}
        # pages the slot may still need for writes it has not issued yet
        self._reserved = np.zeros(max_slots, np.int64)
        self.version = 0        # bumped on every table mutation
        self.stats = _Stats()

    # ---------------------------------------------------------- helpers

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_reserved(self) -> int:
        return int(self._reserved.sum())

    def _alloc(self) -> int:
        if not self._free:
            # Reservations make this unreachable for admitted requests;
            # reaching it means the accounting is broken.
            raise KVPoolExhausted(
                "internal error: free list empty despite reservation")
        p = self._free.pop()
        self.refcount[p] = 1
        self.stats.pages_allocated += 1
        return p

    def _release_page(self, p: int) -> None:
        if self.refcount[p] <= 0:
            # A slot-level double release is a harmless no-op (the table
            # row is already -1); reaching a page twice means a table /
            # refcount divergence — fail loudly instead of corrupting
            # the free list.
            raise ValueError(
                f"double release of page {p} (refcount "
                f"{int(self.refcount[p])})")
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            h = self._hash_of.pop(p, None)
            if h is not None:
                self._by_hash.pop(h, None)
            self._free.append(p)

    @staticmethod
    def _page_digests(tokens, page_size: int) -> list[bytes]:
        """Prefix-chained digest per prompt page (partial tail page
        included; its digest folds in the token count so prefixes of
        different lengths inside one page never collide)."""
        toks = np.asarray(tokens, np.int32)
        out, prev = [], b""
        for start in range(0, len(toks), page_size):
            chunk = toks[start:start + page_size]
            h = hashlib.sha1(prev + np.int64(len(chunk)).tobytes()
                             + chunk.tobytes()).digest()
            out.append(h)
            prev = h
        return out

    def _plan(self, tokens, max_new: int):
        """(digests, shared phys per prompt page or None, need_now,
        reserve) — the dry-run shared by can_admit and admit_slot."""
        ps = self.page_size
        n_tok = len(tokens)
        last = n_tok + max(0, int(max_new)) - 1
        if last // ps >= self.pages_per_slot:
            raise KVPoolExhausted(
                f"request needs page {last // ps} but the table is only "
                f"{self.pages_per_slot} pages wide")
        digests = self._page_digests(tokens, ps)
        hits = [self._by_hash.get(h) for h in digests]
        need_now = sum(1 for p in hits if p is None)
        # Write range [n_tok, last]: reserve one page for EVERY page in
        # it. Beyond-prompt pages cost an alloc; the partial tail prompt
        # page (the only prompt page that can overlap the range) may
        # cost a CoW even when privately owned at admission — it sits in
        # the hash registry, so a later request can share it and turn
        # the owner's next write into a copy. Tail reservations that end
        # up unused (the page never gets shared) are held until release:
        # one page of pessimism per active slot buys the guarantee that
        # prepare_write never fails.
        first_w, last_w = n_tok // ps, last // ps
        reserve = last_w - first_w + 1 if max_new > 0 else 0
        return digests, hits, need_now, reserve

    # ------------------------------------------------------------ admit

    def can_admit(self, tokens, max_new: int) -> bool:
        """True iff ``admit_slot`` would succeed right now."""
        try:
            _, _, need_now, reserve = self._plan(tokens, max_new)
        except KVPoolExhausted:
            return False
        return self.n_free - self.n_reserved >= need_now + reserve

    def admit_slot(self, slot: int, tokens, max_new: int) -> AdmitPlan:
        """Map slot's prompt pages (sharing where digests match) and
        reserve its full write range. Raises KVPoolExhausted when the
        pool cannot guarantee the request end-to-end."""
        if np.any(self.table[slot] >= 0) or self._reserved[slot]:
            raise ValueError(f"slot {slot} already mapped")
        digests, hits, need_now, reserve = self._plan(tokens, max_new)
        if self.n_free - self.n_reserved < need_now + reserve:
            self.stats.refused += 1
            raise KVPoolExhausted(
                f"need {need_now} pages now + {reserve} reserved, pool has "
                f"{self.n_free} free ({self.n_reserved} already reserved)")
        shared, private = [], []
        for j, (h, hit) in enumerate(zip(digests, hits)):
            if hit is not None:
                self.refcount[hit] += 1
                self.table[slot, j] = hit
                shared.append((j, hit))
                self.stats.shared_page_hits += 1
            else:
                p = self._alloc()
                self.table[slot, j] = p
                self._by_hash[h] = p
                self._hash_of[p] = h
                private.append((j, p))
        self._reserved[slot] = reserve
        self.version += 1
        self.stats.admitted += 1
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       int((self.refcount > 0).sum()))
        self.stats.peak_sharing = max(self.stats.peak_sharing,
                                      self.sharing_ratio())
        return AdmitPlan(slot, tuple(shared), tuple(private))

    # ------------------------------------------------------------ write

    def prepare_write(self, slot: int, pos: int) -> PageWrite | None:
        """Make position ``pos`` of ``slot`` privately writable. Returns
        the copy/alloc directive the engine must execute on the device
        arrays, or None when the page is already private."""
        j = int(pos) // self.page_size
        phys = int(self.table[slot, j])
        if phys < 0:
            dst = self._alloc()
            self.table[slot, j] = dst
            self._reserved[slot] = max(0, self._reserved[slot] - 1)
            self.version += 1
            return PageWrite("alloc", j, dst)
        if self.refcount[phys] > 1:
            dst = self._alloc()
            self.refcount[phys] -= 1
            self.table[slot, j] = dst
            self._reserved[slot] = max(0, self._reserved[slot] - 1)
            self.version += 1
            self.stats.cow_copies += 1
            return PageWrite("cow", j, dst, src=phys)
        return None

    # ---------------------------------------------------------- release

    def release_slot(self, slot: int) -> None:
        """Drop all of slot's references; pages reaching refcount 0 go
        back to the free list (and out of the hash registry)."""
        for j in range(self.pages_per_slot):
            p = int(self.table[slot, j])
            if p >= 0:
                self._release_page(p)
                self.table[slot, j] = -1
        self._reserved[slot] = 0
        self.version += 1

    # ------------------------------------------------------------ stats

    def sharing_ratio(self) -> float:
        """Logical mapped pages per physical resident page (> 1 means
        prefix sharing is active)."""
        logical = int((self.table >= 0).sum())
        physical = int((self.refcount > 0).sum())
        return logical / physical if physical else 1.0

    def report(self) -> dict:
        s = self.stats
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_free": self.n_free,
            "pages_reserved": self.n_reserved,
            "pages_resident": int((self.refcount > 0).sum()),
            "sharing_ratio": self.sharing_ratio(),
            "admitted": s.admitted,
            "refused": s.refused,
            "shared_page_hits": s.shared_page_hits,
            "cow_copies": s.cow_copies,
            "pages_allocated": s.pages_allocated,
            "peak_resident": s.peak_resident,
            "peak_sharing_ratio": s.peak_sharing,
        }
