"""Slot-level scheduler: fixed pool of cache slots, FCFS admission.

The scheduler is pure bookkeeping — it owns which request sits in which
slot and who is admitted next; the engine owns the device arrays (the
per-slot `pos` vector and the batched cache) that mirror its decisions.

Admission policy: FCFS over submission order among *eligible* waiters.
A waiter is eligible once it has arrived on the engine clock AND its
preemption-resume backoff (`resume_at`) has elapsed; expired waiters
(deadline passed before admission) are dropped by `drop_expired`
instead of ever occupying a slot. A later request never jumps an
eligible head even if a deeper slot would fit it — the only head-of-
line relaxation is skipping waiters that are not eligible *yet*
(un-arrived, or backing off after a preemption), which is what keeps a
preempted victim from stalling the queue it was evicted to unblock.

Preemption (`preempt`) moves an ACTIVE request back to WAITING, re-
inserted in original submission (rid) order so it does not lose its
place permanently; the engine pairs this with a resume backoff and a
per-request retry budget to bound churn.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from repro.serving.request import ACTIVE, EXPIRED, FINISHED, WAITING, Request


class SlotScheduler:
    def __init__(self, max_slots: int):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.max_slots = max_slots
        self._free: List[int] = list(range(max_slots - 1, -1, -1))
        self._waiting: deque[Request] = deque()
        self._active: Dict[int, Request] = {}

    # -- submission ----------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.status != WAITING:
            raise ValueError(
                f"request {req.rid} submitted with status {req.status!r}; "
                f"only {WAITING!r} requests can join the queue")
        if req in self._waiting:
            raise ValueError(f"request {req.rid} is already queued")
        self._waiting.append(req)

    # -- admission -----------------------------------------------------
    def _eligible(self, req: Request, now: float) -> bool:
        return req.arrival_time <= now and req.resume_at <= now

    def next_admission(self, now: float) -> Optional[Request]:
        """First eligible waiter in queue order if a slot is free."""
        if not self._free:
            return None
        for req in self._waiting:
            if self._eligible(req, now):
                return req
        return None

    def admit(self, req: Request) -> int:
        """Bind a waiting request to a free slot; returns the slot id."""
        try:
            self._waiting.remove(req)
        except ValueError:
            raise ValueError(
                f"request {req.rid} is not in the waiting queue "
                f"(status {req.status!r})") from None
        if not self._free:
            raise ValueError(
                f"no free slot to admit request {req.rid} into")
        slot = self._free.pop()
        req.slot = slot
        req.status = ACTIVE
        self._active[slot] = req
        return slot

    def drop_expired(self, now: float) -> List[Request]:
        """Remove waiters whose deadline has already passed; they are
        marked EXPIRED and returned for the engine's accounting."""
        dropped = []
        for req in list(self._waiting):
            if req.deadline is not None and req.deadline < now:
                self._waiting.remove(req)
                req.status = EXPIRED
                dropped.append(req)
        return dropped

    def remove_waiting(self, req: Request) -> None:
        """Take a waiter out of the queue (cancellation)."""
        try:
            self._waiting.remove(req)
        except ValueError:
            raise ValueError(
                f"request {req.rid} is not waiting (status "
                f"{req.status!r})") from None

    # -- release / preemption ------------------------------------------
    def release(self, slot: int, status: str = FINISHED) -> Request:
        """Free an active slot; the departing request gets `status`."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not active; cannot release")
        req = self._active.pop(slot)
        req.status = status
        req.slot = -1
        self._free.append(slot)
        return req

    def preempt(self, slot: int, *, resume_at: float = 0.0) -> Request:
        """Evict an active request back to the waiting queue, keeping
        its original submission-order position (rid order) so a resumed
        victim is next in line once its backoff elapses."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not active; cannot preempt")
        req = self._active.pop(slot)
        req.status = WAITING
        req.slot = -1
        req.resume_at = resume_at
        self._free.append(slot)
        idx = next((i for i, w in enumerate(self._waiting)
                    if w.rid > req.rid), len(self._waiting))
        self._waiting.insert(idx, req)
        return req

    # -- introspection -------------------------------------------------
    @property
    def active(self) -> Dict[int, Request]:
        return dict(self._active)

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def next_arrival_time(self) -> Optional[float]:
        """Earliest time any waiter becomes eligible, or None."""
        if not self._waiting:
            return None
        return min(max(w.arrival_time, w.resume_at) for w in self._waiting)
