"""Slot-level scheduler: fixed pool of cache slots, FCFS admission.

The scheduler is pure bookkeeping — it owns which request sits in which
slot and who is admitted next; the engine owns the device arrays (the
per-slot `pos` vector and the batched cache) that mirror its decisions.

Admission policy: strict FCFS over arrival order. The head of the
waiting queue is admitted as soon as (a) it has arrived on the engine
clock and (b) a slot is free; later requests never jump the head even
if a deeper slot would fit them (no head-of-line reordering — keeps
latency analysis honest).
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from repro.serving.request import ACTIVE, FINISHED, WAITING, Request


class SlotScheduler:
    def __init__(self, max_slots: int):
        assert max_slots >= 1
        self.max_slots = max_slots
        self._free: List[int] = list(range(max_slots - 1, -1, -1))
        self._waiting: deque[Request] = deque()
        self._active: Dict[int, Request] = {}

    # -- submission ----------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.status == WAITING
        self._waiting.append(req)

    # -- admission -----------------------------------------------------
    def next_admission(self, now: float) -> Optional[Request]:
        """FCFS head if it has arrived and a slot is free, else None."""
        if not self._free or not self._waiting:
            return None
        head = self._waiting[0]
        if head.arrival_time > now:
            return None
        return head

    def admit(self, req: Request) -> int:
        """Bind the queue head to a free slot; returns the slot id."""
        assert self._waiting and self._waiting[0] is req
        self._waiting.popleft()
        slot = self._free.pop()
        req.slot = slot
        req.status = ACTIVE
        self._active[slot] = req
        return slot

    # -- release -------------------------------------------------------
    def release(self, slot: int) -> None:
        req = self._active.pop(slot)
        req.status = FINISHED
        req.slot = -1
        self._free.append(slot)

    # -- introspection -------------------------------------------------
    @property
    def active(self) -> Dict[int, Request]:
        return dict(self._active)

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def next_arrival_time(self) -> Optional[float]:
        return self._waiting[0].arrival_time if self._waiting else None
