"""AdamW + schedules + clipping, as pure pytree transforms (no optax
dependency — the substrate is built, not assumed).

Optimizer state lives in whatever sharding the params use (the
launcher shards both identically => ZeRO-style state sharding for
free under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"    # bf16 option halves optimizer HBM

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        dt = jnp.dtype(self.state_dtype)
        m = jax.tree.map(
            lambda mm, g: (b1 * mm.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(dt),
            state.m, grads)
        v = jax.tree.map(
            lambda vv, g: (b2 * vv.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(dt),
            state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mhat = mm.astype(jnp.float32) / bc1
            vhat = vv.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), gnorm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
