"""zamba2-1.2b [hybrid]: 38L d_model=2048, Mamba2 backbone with ONE
weight-shared attention+MLP block (32H kv=32, d_ff=8192) invoked every
6 layers with per-invocation LoRA, ssm_state=64. [arXiv:2411.15242]

Simplification recorded (DESIGN §6): the shared block consumes
concat(hidden, embedding) through a learned 2d->d projection; Zamba2's
dual shared blocks are represented by the single shared block + LoRA.
36 of 38 layers fall into 6 shared-block segments; the trailing 2
layers are folded into the last segment period (attn_every=6 exact via
n_layers=36+2 -> we use 36 scanned segment layers + 2 extra handled by
segment count 6; recorded as 38 layers total with segments of 6 and a
final segment of 8).  For scan regularity we round to 36 mamba layers
in 6 segments + 2 standalone mamba layers appended.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=36,                # 6 segments x 6 (see note above)
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    attn_every=6,
    shared_attn_lora_rank=32,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  chunk=128, n_groups=1),
)
