"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). d_inner = 2*2560 = 5120,
head_dim 64 -> 80 SSD heads. [arXiv:2405.21060]
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,                  # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256, n_groups=1),
)
