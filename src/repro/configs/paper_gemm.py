"""The paper's own workload: 4096x4096 GEMM (and add/sub) in
float / double / complex-float — Table 2 / Figs 7-9.

Not a model config; consumed by benchmarks/ and examples/.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    n: int = 4096
    dtypes: tuple = ("float32", "float64", "complex64")
    block: int = 16          # the paper's CUDA block edge
    # Table 2 wall-clock seconds (for the modeled comparison)
    reference_times = {
        ("xeon-e7-4860", "float32"): 991.96,
        ("xeon-e7-4860", "float64"): 1455.27,
        ("xeon-e7-4860", "complex64"): 1679.15,
        ("tesla-c2050", "float32"): 2.49,
        ("tesla-c2050", "float64"): 3.13,
        ("tesla-c2050", "complex64"): 4.17,
        ("tesla-c2050-shared", "float32"): 0.83,
        ("tesla-c2050-shared", "float64"): 1.60,
        ("tesla-c2050-shared", "complex64"): 2.07,
        ("tesla-c1060", "float32"): 5.81,
        ("tesla-c1060", "float64"): 8.56,
        ("tesla-c1060", "complex64"): 18.07,
    }


CONFIG = PaperWorkload()
