"""Model/runtime configuration.

One `ModelConfig` describes any architecture in the assigned pool; the
per-arch modules in this package instantiate it with the exact public
dims. `reduced()` derives the CPU smoke-test config (same family, tiny
dims) required by the spec.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 256           # tokens per routing group (GShard-style)
    dense_ff: int = 0               # Arctic: parallel dense-residual FFN width
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    # "einsum": GShard one-hot dispatch — O(tokens*E*C) bytes but cleanly
    #   SPMD-partitionable (default; E*C per token = top_k*S*cf, so the
    #   group size S controls the memory).
    # "gather": index-based dispatch — O(tokens*topk) bytes, but XLA's
    #   partitioner cannot batch-partition the scatter at jit level and
    #   replicates instead (measured: 28 GiB all-gathers per layer on
    #   arctic-480b; see EXPERIMENTS §Perf). Used on single-host paths.
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention flavour
    window: Optional[int] = None    # sliding-window size (Mixtral)
    qk_norm: bool = False           # Qwen3
    qkv_bias: bool = False          # Qwen1.5 / Qwen2-VL
    rope_theta: float = 10_000.0
    use_rope: bool = True           # Whisper uses absolute embeddings
    mrope_sections: Optional[Tuple[int, int, int]] = None  # Qwen2-VL M-RoPE
    tie_embeddings: bool = False
    # MoE / SSM / hybrid extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0             # hybrid: shared attn block period (Zamba2)
    shared_attn_lora_rank: int = 0  # Zamba2 per-invocation LoRA on shared block
    # encoder-decoder (Whisper)
    n_enc_layers: int = 0
    enc_ctx: int = 0                # encoder frames (stub frontend output)
    # block flavour
    norm: str = "rms"               # rms | ln (Whisper)
    mlp: str = "swiglu"             # swiglu | gelu (Whisper)
    # sharding behaviour
    # When kv/q heads don't divide the model axis (qwen1.5: 40 heads on
    # a 16-wide axis), shard the q-sequence dim instead of replicating
    # attention activations (context parallelism). Off in the
    # paper-faithful baseline; §Perf iteration 1.
    shard_attn_seq: bool = False
    # "free": leave non-divisible attention dims UNCONSTRAINED (XLA may
    # factor 40 heads as 8x2); "replicate": force replication (the
    # original baseline semantics, kept for §Perf before/after).
    constrain_mode: str = "free"
    # f32 attention I/O (baseline) vs bf16 I/O with f32 accumulation
    # (the Pallas flash kernel's numerics; halves attention-side HBM and
    # the dx all-reduce bytes). §Perf lever.
    attn_f32_io: bool = True
    # numerics / compilation
    vocab_pad_to: int = 256         # Megatron-style vocab padding (shardability)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"             # none | full | dots
    scan_layers: bool = True
    max_position: int = 1 << 20
    # activation attention chunking (XLA online-softmax path)
    attn_chunk: int = 2048

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (DESIGN §6)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def reduced(self) -> "ModelConfig":
        """Same family, toy dims — the per-arch CPU smoke config."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=16,
            d_ff=128,
            vocab=256,
            max_position=4096,
            attn_chunk=64,
        )
        if self.mrope_sections is not None:
            kw["mrope_sections"] = (2, 3, 3)   # head_dim 16 -> 8 freq slots
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                group_size=32,
                dense_ff=64 if self.moe.dense_ff else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=8, chunk=16)
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_ctx"] = 32
        if self.attn_every:
            kw["attn_every"] = 2
        if self.window is not None:
            kw["window"] = 32
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) column: what to lower and how big."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
