"""arctic-480b [moe]: 35L d_model=7168 56H (kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a parallel dense residual FFN branch
(dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base]

Assumption recorded: the dense residual branch width is set to d_model
(7168); the hf config's dense branch is the 10B dense trunk's FFN.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, dense_ff=7168,
                  capacity_factor=1.0),
)
