"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Enc-dec; the conv frontend is a STUB per the task spec — input_specs()
provides precomputed frame embeddings (B, 1500, 384). Whisper uses
LayerNorm + GELU + absolute (sinusoidal) positions, no RoPE.
[arXiv:2212.04356]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                 # decoder layers
    n_enc_layers=4,
    enc_ctx=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    use_rope=False,
    norm="ln",
    mlp="gelu",
    tie_embeddings=True,
)
