"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (kv=2) d_ff=8960
vocab=151936, M-RoPE, dynamic resolution. [arXiv:2409.12191]

Vision frontend is a STUB per spec: input_specs() supplies aligned
patch embeddings (added to the token embedding grid) plus (t, h, w)
M-RoPE position streams. head_dim = 1536/12 = 128 -> mrope sections
(16, 24, 24) over the 64 frequency slots, per the hf config.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
)
