"""Config registry: one module per assigned architecture.

`get_config(name)` returns the full published config; `get_config(name,
reduced=True)` the CPU smoke-test derivative.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, SHAPES, get_shape

from repro.configs import (
    whisper_tiny, mixtral_8x22b, arctic_480b, qwen2_vl_2b, qwen3_0_6b,
    qwen1_5_32b, granite_20b, granite_3_8b, zamba2_1_2b, mamba2_2_7b,
    paper_gemm,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_tiny, mixtral_8x22b, arctic_480b, qwen2_vl_2b, qwen3_0_6b,
        qwen1_5_32b, granite_20b, granite_3_8b, zamba2_1_2b, mamba2_2_7b,
    )
}

ARCH_NAMES = tuple(sorted(_REGISTRY))


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    cfg = _REGISTRY[name]
    return cfg.reduced() if reduced else cfg
