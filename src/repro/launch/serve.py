"""Serving CLI — a thin driver over the continuous-batching engine.

Mixed-length arrival trace (the production shape):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 12 --max-slots 4 --arrival-rate 2

Uniform single batch (the degenerate case: all slots admitted at t=0,
equal lengths — byte-compatible with the pre-engine driver):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Chaos mode (deterministic fault injection; the run must SURVIVE):

    PYTHONPATH=src python -m repro.launch.serve --reduced --requests 8 \
        --max-slots 2 --kv-layout paged --page-size 8 \
        --chaos-nan-step 3 --chaos-deny-admissions 2

Speculative decoding (draft proposes, target verifies in one batched
forward; --check-exact pins greedy token-exactness vs the plain dense
reference engine):

    PYTHONPATH=src python -m repro.launch.serve --reduced --requests 8 \
        --arch qwen1.5-32b --draft qwen3-0.6b --spec-k 4 --check-exact

Named workload scenarios (serving.workload.TRACES):

    PYTHONPATH=src python -m repro.launch.serve --reduced --requests 8 \
        --workload bursty --arrival-rate 4

The engine (repro.serving) owns slot scheduling, per-slot prefill and
the shared jitted serve_step with a per-slot `pos` vector; this module
only builds a synthetic workload, constructs the execution Policy from
--backend/--autotune, optionally arms the serving FaultInjector, and
reports per-request latency plus aggregate throughput and goodput.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import tuning
from repro.configs import ARCH_NAMES, get_config
from repro.core import policy as policy_mod
from repro.core.policy import LEGACY_BACKEND_NAMES, Policy
from repro.models import model as M
from repro.serving import DEFAULT_PREFILL_CHUNK, FaultInjector, \
    ServingEngine, TRACES, make_sampler, make_trace, prefix_heavy_trace, \
    synthetic_trace
from repro.serving.request import FINISHED


def build_workload(cfg, args, rng):
    """Synthetic trace (TraceItem list): a named scenario from the
    workload registry when --workload is set; otherwise prefix-heavy
    chat when --prefix-len is set, mixed-length Poisson when --requests
    is set, else the uniform degenerate batch. Deadlines, priorities
    and bursty arrivals apply throughout."""
    ft = dict(deadline=args.deadline or None,
              priority_levels=tuple(int(p) for p in
                                    args.priority_levels.split(",")),
              burst_size=args.burst_size)
    if args.workload:
        n = args.requests or args.batch
        kw = dict(gen=args.gen, arrival_rate=args.arrival_rate, **ft)
        if args.workload == "bursty":
            # compound-Poisson group sizes replace the fixed burst knob
            kw.pop("burst_size")
        return make_trace(args.workload, cfg, n, rng=rng, **kw)
    if args.prefix_len:
        n = args.requests or args.batch
        return prefix_heavy_trace(cfg, n, rng=rng,
                                  prefix_len=args.prefix_len,
                                  suffix_range=(args.suffix_min,
                                                args.suffix_max),
                                  gen=args.gen,
                                  arrival_rate=args.arrival_rate, **ft)
    if args.requests:
        len_range = (args.prompt_len_min, args.prompt_len_max)
        return synthetic_trace(cfg, args.requests, rng=rng,
                               len_range=len_range, gen=args.gen,
                               arrival_rate=args.arrival_rate, **ft)
    return synthetic_trace(cfg, args.batch, rng=rng,
                           len_range=(args.prompt_len, args.prompt_len),
                           gen=args.gen, arrival_rate=0.0, **ft)


def build_injector(args):
    """FaultInjector from the --chaos-* flags, or None when unarmed."""
    steps = lambda s: tuple(int(x) for x in s.split(",")) if s else ()
    nan_rows = ({int(args.chaos_nan_step): int(args.chaos_nan_slot)}
                if args.chaos_nan_step >= 0 else {})
    corrupt = ({int(args.chaos_corrupt_step): int(args.chaos_corrupt_slot)}
               if args.chaos_corrupt_step >= 0 else {})
    slow = {s: args.chaos_slow_seconds
            for s in steps(args.chaos_slow_steps)}
    kernel = steps(args.chaos_kernel_steps)
    deny = steps(args.chaos_deny_admissions)
    if not (nan_rows or corrupt or slow or kernel or deny):
        return None
    return FaultInjector(nan_rows=nan_rows, corrupt_pages=corrupt,
                         kernel_fail_steps=kernel, slow_steps=slow,
                         deny_admissions=deny)


def check_outputs(cfg, engine, requests):
    """Hard output contract (replaces the vacuous isfinite-on-int check):
    every emitted token is a real vocab id, the engine's aggregate token
    count matches the per-request streams, every request reached a
    terminal state, and FINISHED requests generated their full quota."""
    for req in requests:
        toks = np.asarray(req.generated)
        if req.status == FINISHED:
            assert toks.size == req.max_new_tokens or (
                engine.eos_id is not None and toks[-1] == engine.eos_id), \
                (req.rid, toks.size, req.max_new_tokens)
        if toks.size:
            assert ((toks >= 0) & (toks < cfg.vocab)).all(), \
                (req.rid, toks.min(), toks.max(), cfg.vocab)
    n_emitted = sum(r.n_generated for r in requests)
    assert n_emitted == engine.tokens_emitted, \
        (n_emitted, engine.tokens_emitted)
    assert engine.scheduler.n_active == 0 and engine.scheduler.n_waiting == 0


def check_chaos(engine, report, requests):
    """Hard survival contract for chaos runs: the engine drained the
    trace with zero crashed steps, nonzero goodput, and terminal-status
    accounting that sums to the trace."""
    assert report["crashed_steps"] == 0, report
    assert report["goodput"] > 0.0, report
    assert report["useful_tokens"] > 0, report
    terminal = (report["n_finished"] + report["expired"]
                + report["cancelled"] + report["quarantined"])
    assert terminal == len(requests), (terminal, len(requests), report)
    inj = report["faults_injected"]
    # an armed injector whose script never fired (e.g. a fault aimed at
    # a slot that never went active) is a chaos run that tested nothing
    # — fail loudly so the script gets fixed, not trusted
    assert sum(inj.values()) > 0, f"no scripted fault fired: {inj}"
    print(f"chaos: survived {sum(inj.values())} injected fault(s) "
          f"({inj}); goodput {report['goodput']:.2f}, "
          f"quarantined {report['quarantined']}, "
          f"preempted {report['preempted']}, "
          f"degraded={report['degraded']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    # mixed-length trace mode
    ap.add_argument("--requests", type=int, default=0,
                    help="number of requests in the synthetic trace "
                         "(0 = uniform single-batch mode)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = burst at t=0)")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="cache slot pool size (default: --batch, or 4)")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=48)
    # uniform-batch mode (the degenerate case) + shared knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sampler", choices=("greedy", "temperature"),
                    default="greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--backend", choices=LEGACY_BACKEND_NAMES, default="xla",
                    help="GEMM backend for every dense contraction; "
                         "constructs the engine's execution Policy "
                         "(tuned = pallas with autotuner-cached tiles)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune uncached GEMM shapes at startup")
    # paged KV cache (serving.kv_pool) + prefix-heavy chat workload
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="KV cache layout: per-slot rows, or a shared "
                         "page pool with prefix sharing + copy-on-write")
    ap.add_argument("--quant-kv", choices=("off", "int8"), default="off",
                    help="int8 KV pages (requires --kv-layout paged)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page in paged mode")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="physical page pool size (0 = dense-equivalent "
                         "capacity: max_slots * pages_per_slot)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared system-prompt length: > 0 switches the "
                         "workload to the prefix-heavy chat trace")
    ap.add_argument("--suffix-min", type=int, default=2)
    ap.add_argument("--suffix-max", type=int, default=12)
    ap.add_argument("--workload", choices=sorted(TRACES), default="",
                    help="named scenario from the workload registry "
                         "(overrides the implicit trace selection)")
    # speculative decoding (serving.spec)
    ap.add_argument("--draft", choices=ARCH_NAMES, default="",
                    help="draft model arch: enables speculative decoding "
                         "(draft proposes --spec-k tokens per round, the "
                         "target verifies them in ONE batched forward)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--check-exact", action="store_true",
                    help="re-run the trace on a dense f32-KV reference "
                         "engine and assert identical token streams "
                         "(greedy sampling only)")
    # fault-tolerance knobs (workload-side)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline, seconds after arrival "
                         "(0 = no deadlines)")
    ap.add_argument("--priority-levels", type=str, default="0",
                    help="comma-separated priority levels sampled "
                         "uniformly per request (e.g. '0,1')")
    ap.add_argument("--burst-size", type=int, default=1,
                    help="requests per arrival burst (> 1 = bursty "
                         "arrivals at the same long-run rate)")
    # chaos harness (serving.faults.FaultInjector; all deterministic)
    ap.add_argument("--chaos-nan-step", type=int, default=-1,
                    help="decode step at which to NaN one slot's logits "
                         "row (-1 = off)")
    ap.add_argument("--chaos-nan-slot", type=int, default=0)
    ap.add_argument("--chaos-corrupt-step", type=int, default=-1,
                    help="decode step at which to NaN-poison one slot's "
                         "private KV page (-1 = off; paged mode)")
    ap.add_argument("--chaos-corrupt-slot", type=int, default=0)
    ap.add_argument("--chaos-kernel-steps", type=str, default="",
                    help="comma-separated decode steps raising a "
                         "simulated kernel fault (retry -> xla degrade)")
    ap.add_argument("--chaos-slow-steps", type=str, default="",
                    help="comma-separated decode steps slowed by "
                         "--chaos-slow-seconds (straggler flagging)")
    ap.add_argument("--chaos-slow-seconds", type=float, default=0.05)
    ap.add_argument("--chaos-deny-admissions", type=str, default="",
                    help="comma-separated admission ordinals forced to "
                         "see an exhausted KV pool (preemption path; "
                         "paged mode)")
    args = ap.parse_args(argv)
    if args.check_exact and args.sampler != "greedy":
        ap.error("--check-exact requires --sampler greedy")

    cfg = get_config(args.arch, reduced=args.reduced)
    policy = Policy.from_backend(args.backend)
    policy = policy.replace(kv_layout=args.kv_layout, quant_kv=args.quant_kv)
    policy_mod.set_default_policy(policy)
    rng = np.random.default_rng(args.seed)
    work = build_workload(cfg, args, rng)
    injector = build_injector(args)

    max_slots = args.max_slots or (args.batch if not args.requests else 4)
    max_len = max(len(it.prompt) + it.gen for it in work)
    if policy.autotune == "cached" or args.autotune:
        # Warm the cache for the shapes the engine actually executes:
        # admission prefill runs at batch 1 over chunk-bucketed prompt
        # lengths plus one-token remainder steps (engine.prefill_chunk
        # floors each prompt), decode at max_slots rows x 1 token.
        chunk = DEFAULT_PREFILL_CHUNK
        buckets = sorted({(len(it.prompt) - len(it.prompt) % chunk)
                          or len(it.prompt) for it in work} | {1})
        wpol = policy if policy.autotune == "cached" else None
        rep = tuning.warm_start(cfg, 1, buckets, policy=wpol,
                                autotune=args.autotune)
        print(tuning.describe_warm_start(rep))
        # decode attends over the engine's cache depth, which rounds
        # max_len up to an attn_chunk multiple (engine.__init__)
        a = cfg.attn_chunk
        cache_len = max_len + (a - max_len % a if max_len > a
                               and max_len % a else 0)
        rep = tuning.warm_start(cfg, max_slots, 1, policy=wpol,
                                autotune=args.autotune,
                                decode_len=cache_len)
        print(tuning.describe_warm_start(rep))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    sampler = make_sampler(args.sampler, temperature=args.temperature,
                           top_k=args.top_k, seed=args.seed)
    draft = None
    if args.draft:
        dcfg = get_config(args.draft, reduced=args.reduced)
        draft = (dcfg, M.init_params(dcfg, jax.random.PRNGKey(args.seed + 1)))
    engine = ServingEngine(cfg, params, max_slots=max_slots,
                           max_len=max_len, sampler=sampler, policy=policy,
                           page_size=args.page_size,
                           kv_pool_pages=args.kv_pool_pages or None,
                           fault_injector=injector,
                           draft=draft, spec_k=args.spec_k)
    requests = [engine.submit(it.prompt, it.gen, arrival_time=it.arrival,
                              deadline=it.deadline, priority=it.priority,
                              enc_frames=it.enc_frames)
                for it in work]
    report = engine.run()

    for r in requests:
        lat = f"{r.latency*1e3:7.1f}ms" if r.latency is not None else "   --  "
        ttft = f"{r.ttft*1e3:7.1f}ms" if r.ttft is not None else "   --  "
        print(f"req {r.rid:3d} prompt={r.prompt_len:3d} "
              f"gen={r.n_generated:3d} ttft={ttft} latency={lat} "
              f"[{r.status}]" + (f" {r.error}" if r.error else ""))
    print(f"arch={cfg.name} slots={max_slots} requests={len(requests)} "
          f"prefill {report['prefill_tok_s']:.1f} tok/s, "
          f"decode {report['decode_tok_s']:.1f} tok/s "
          f"(occupancy {report['mean_occupancy']:.2f}/{max_slots}), "
          f"latency p50 {report['latency_p50_s']*1e3:.0f}ms "
          f"p95 {report['latency_p95_s']*1e3:.0f}ms, "
          f"ttft p50 {report['ttft_p50_s']*1e3:.0f}ms")
    print(f"fault tolerance: goodput {report['goodput']:.2f} "
          f"({report['useful_tokens']}/{engine.tokens_emitted} tokens), "
          f"expired {report['expired']}, cancelled {report['cancelled']}, "
          f"preempted {report['preempted']}, "
          f"quarantined {report['quarantined']}, "
          f"deadline-miss rate {report['deadline_miss_rate']:.2f}, "
          f"stragglers {report['straggler_steps']}")
    if "spec_acceptance_rate" in report:
        print(f"speculative: draft={args.draft} k={args.spec_k}, "
              f"{report['spec_rounds']} rounds, acceptance "
              f"{report['spec_acceptance_rate']:.2f} "
              f"({report['spec_accepted']}/{report['spec_proposed']}), "
              f"tokens/step {report['tokens_per_step']:.2f}, "
              f"draft time {report['draft_time_s']*1e3:.0f}ms")
    if "kv_pool" in report:
        kv = report["kv_pool"]
        print(f"kv pool: {kv['n_pages']} pages x {kv['page_size']} tok, "
              f"peak resident {kv['peak_resident']}, "
              f"peak sharing {kv['peak_sharing_ratio']:.2f}x, "
              f"{kv['shared_page_hits']} shared hits, "
              f"{kv['cow_copies']} CoW copies")
    check_outputs(cfg, engine, requests)
    if injector is not None:
        check_chaos(engine, report, requests)

    if args.check_exact:
        # Same trace, dense rows, full-precision KV, NO draft: the
        # paged / int8 / speculative engine must emit byte-identical
        # greedy token streams vs the plain reference.
        ref_pol = policy.replace(kv_layout="dense", quant_kv="off")
        ref = ServingEngine(
            cfg, params, max_slots=max_slots, max_len=max_len,
            sampler=make_sampler(args.sampler, seed=args.seed),
            policy=ref_pol)
        ref_reqs = [ref.submit(it.prompt, it.gen, arrival_time=it.arrival,
                               enc_frames=it.enc_frames)
                    for it in work]
        ref.run()
        # Under chaos, requests the injector terminated early carry
        # deliberately partial streams; every request that FINISHED must
        # still match the fault-free dense reference token-for-token.
        n_cmp = 0
        for a, b in zip(requests, ref_reqs):
            if injector is not None and a.status != FINISHED:
                continue
            assert a.generated == b.generated, \
                (a.rid, a.generated, b.generated)
            n_cmp += 1
        assert n_cmp > 0, "no finished requests to compare"
        if "kv_pool" in report and args.prefix_len:
            assert report["kv_pool"]["peak_sharing_ratio"] > 1.0, \
                report["kv_pool"]
        print(f"check-exact: {n_cmp} token streams match the "
              f"dense reference")

    if not args.requests:
        # degenerate mode keeps the pre-engine return contract:
        # (batch, gen) int32 token grid, submission order
        gen = np.stack([np.asarray(r.generated, np.int32)
                        for r in requests])
        print("generated ids[0,:16]:", gen[0, :16].tolist())
        return gen
    return report


if __name__ == "__main__":
    main()
