"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Serving loop structure (the production shape of it):
  * one jitted prefill (fills the KV/state cache, returns first token)
  * one jitted serve_step reused for every subsequent token
  * continuous batching hooks: the cache is (B, ...) and `pos` is
    per-batch-uniform here; slot-level scheduling is the next layer up.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import tuning
from repro.configs import ARCH_NAMES, get_config
from repro.core import gemm
from repro.kernels import ops as kops
from repro.models import model as M
from repro.training import train_loop as TL


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=kops.MATMUL_BACKENDS, default="xla",
                    help="GEMM backend for every dense contraction "
                         "(tuned = autotuner-cached tiles)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune uncached GEMM shapes at startup")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    gemm.set_default_backend(args.backend)
    if args.backend.startswith("tuned") or args.autotune:
        # Warm the cache under the SAME exec backend the runtime lookup
        # resolves to, for the shapes it actually sees: prefill GEMMs
        # have batch*prompt_len rows, decode GEMMs batch*1 rows.
        rep = tuning.warm_start(
            cfg, args.batch, (args.prompt_len, 1),
            backend=kops.resolve_tuned(args.backend)
            if args.backend.startswith("tuned") else None,
            autotune=args.autotune)
        print(tuning.describe_warm_start(rep))
    rng = np.random.default_rng(args.seed)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    b, t = args.batch, args.prompt_len
    max_len = t + args.gen
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, t)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((b, t, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
        pos = np.broadcast_to(np.arange(t)[None, :, None], (b, t, 3))
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_ctx, cfg.d_model)), jnp.float32)

    prefill = jax.jit(TL.make_prefill(cfg), donate_argnums=(2,))
    serve_step = jax.jit(TL.make_serve_step(cfg), donate_argnums=(3,))

    cache = M.init_cache(cfg, b, max_len)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = serve_step(params, tok, jnp.int32(t + i), cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} prefill({b}x{t}) {t_prefill*1e3:.0f}ms, "
          f"decode {args.gen-1} steps {t_decode*1e3:.0f}ms "
          f"({(args.gen-1)*b/max(t_decode,1e-9):.1f} tok/s)")
    print("generated ids[0,:16]:", gen[0, :16].tolist())
    assert np.isfinite(gen).all()
    return gen


if __name__ == "__main__":
    main()
