"""Serving CLI — a thin driver over the continuous-batching engine.

Mixed-length arrival trace (the production shape):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 12 --max-slots 4 --arrival-rate 2

Uniform single batch (the degenerate case: all slots admitted at t=0,
equal lengths — byte-compatible with the pre-engine driver):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --batch 4 --prompt-len 32 --gen 16

The engine (repro.serving) owns slot scheduling, per-slot prefill and
the shared jitted serve_step with a per-slot `pos` vector; this module
only builds a synthetic workload, constructs the execution Policy from
--backend/--autotune, and reports per-request latency plus aggregate
throughput.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import tuning
from repro.configs import ARCH_NAMES, get_config
from repro.core import policy as policy_mod
from repro.core.policy import LEGACY_BACKEND_NAMES, Policy
from repro.models import model as M
from repro.serving import DEFAULT_PREFILL_CHUNK, ServingEngine, \
    make_sampler, prefix_heavy_trace, synthetic_trace


def build_workload(cfg, args, rng):
    """Synthetic trace (prompt, max_new, arrival, enc): prefix-heavy
    chat when --prefix-len is set, mixed-length Poisson when --requests
    is set, else the uniform degenerate batch."""
    if args.prefix_len:
        n = args.requests or args.batch
        return prefix_heavy_trace(cfg, n, rng=rng,
                                  prefix_len=args.prefix_len,
                                  suffix_range=(args.suffix_min,
                                                args.suffix_max),
                                  gen=args.gen,
                                  arrival_rate=args.arrival_rate)
    if args.requests:
        len_range = (args.prompt_len_min, args.prompt_len_max)
        return synthetic_trace(cfg, args.requests, rng=rng,
                               len_range=len_range, gen=args.gen,
                               arrival_rate=args.arrival_rate)
    return synthetic_trace(cfg, args.batch, rng=rng,
                           len_range=(args.prompt_len, args.prompt_len),
                           gen=args.gen, arrival_rate=0.0)


def check_outputs(cfg, engine, requests):
    """Hard output contract (replaces the vacuous isfinite-on-int check):
    every emitted token is a real vocab id and the engine's aggregate
    token count matches the per-request streams."""
    for req in requests:
        toks = np.asarray(req.generated)
        assert toks.size == req.max_new_tokens or (
            engine.eos_id is not None and toks[-1] == engine.eos_id), \
            (req.rid, toks.size, req.max_new_tokens)
        assert ((toks >= 0) & (toks < cfg.vocab)).all(), \
            (req.rid, toks.min(), toks.max(), cfg.vocab)
    n_emitted = sum(r.n_generated for r in requests)
    assert n_emitted == engine.tokens_emitted, \
        (n_emitted, engine.tokens_emitted)
    assert engine.scheduler.n_active == 0 and engine.scheduler.n_waiting == 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    # mixed-length trace mode
    ap.add_argument("--requests", type=int, default=0,
                    help="number of requests in the synthetic trace "
                         "(0 = uniform single-batch mode)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = burst at t=0)")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="cache slot pool size (default: --batch, or 4)")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=48)
    # uniform-batch mode (the degenerate case) + shared knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sampler", choices=("greedy", "temperature"),
                    default="greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--backend", choices=LEGACY_BACKEND_NAMES, default="xla",
                    help="GEMM backend for every dense contraction; "
                         "constructs the engine's execution Policy "
                         "(tuned = pallas with autotuner-cached tiles)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune uncached GEMM shapes at startup")
    # paged KV cache (serving.kv_pool) + prefix-heavy chat workload
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="KV cache layout: per-slot rows, or a shared "
                         "page pool with prefix sharing + copy-on-write")
    ap.add_argument("--quant-kv", choices=("off", "int8"), default="off",
                    help="int8 KV pages (requires --kv-layout paged)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page in paged mode")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="physical page pool size (0 = dense-equivalent "
                         "capacity: max_slots * pages_per_slot)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared system-prompt length: > 0 switches the "
                         "workload to the prefix-heavy chat trace")
    ap.add_argument("--suffix-min", type=int, default=2)
    ap.add_argument("--suffix-max", type=int, default=12)
    ap.add_argument("--check-exact", action="store_true",
                    help="re-run the trace on a dense f32-KV reference "
                         "engine and assert identical token streams "
                         "(greedy sampling only)")
    args = ap.parse_args(argv)
    if args.check_exact and args.sampler != "greedy":
        ap.error("--check-exact requires --sampler greedy")

    cfg = get_config(args.arch, reduced=args.reduced)
    policy = Policy.from_backend(args.backend)
    policy = policy.replace(kv_layout=args.kv_layout, quant_kv=args.quant_kv)
    policy_mod.set_default_policy(policy)
    rng = np.random.default_rng(args.seed)
    work = build_workload(cfg, args, rng)

    max_slots = args.max_slots or (args.batch if not args.requests else 4)
    max_len = max(len(p) + g for p, g, _, _ in work)
    if policy.autotune == "cached" or args.autotune:
        # Warm the cache for the shapes the engine actually executes:
        # admission prefill runs at batch 1 over chunk-bucketed prompt
        # lengths plus one-token remainder steps (engine.prefill_chunk
        # floors each prompt), decode at max_slots rows x 1 token.
        chunk = DEFAULT_PREFILL_CHUNK
        buckets = sorted({(len(p) - len(p) % chunk) or len(p)
                          for p, _, _, _ in work} | {1})
        wpol = policy if policy.autotune == "cached" else None
        rep = tuning.warm_start(cfg, 1, buckets, policy=wpol,
                                autotune=args.autotune)
        print(tuning.describe_warm_start(rep))
        # decode attends over the engine's cache depth, which rounds
        # max_len up to an attn_chunk multiple (engine.__init__)
        a = cfg.attn_chunk
        cache_len = max_len + (a - max_len % a if max_len > a
                               and max_len % a else 0)
        rep = tuning.warm_start(cfg, max_slots, 1, policy=wpol,
                                autotune=args.autotune,
                                decode_len=cache_len)
        print(tuning.describe_warm_start(rep))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    sampler = make_sampler(args.sampler, temperature=args.temperature,
                           top_k=args.top_k, seed=args.seed)
    engine = ServingEngine(cfg, params, max_slots=max_slots,
                           max_len=max_len, sampler=sampler, policy=policy,
                           page_size=args.page_size,
                           kv_pool_pages=args.kv_pool_pages or None)
    requests = [engine.submit(p, g, arrival_time=t, enc_frames=enc)
                for p, g, t, enc in work]
    report = engine.run()

    for r in requests:
        print(f"req {r.rid:3d} prompt={r.prompt_len:3d} "
              f"gen={r.n_generated:3d} ttft={r.ttft*1e3:7.1f}ms "
              f"latency={r.latency*1e3:7.1f}ms")
    print(f"arch={cfg.name} slots={max_slots} requests={len(requests)} "
          f"prefill {report['prefill_tok_s']:.1f} tok/s, "
          f"decode {report['decode_tok_s']:.1f} tok/s "
          f"(occupancy {report['mean_occupancy']:.2f}/{max_slots}), "
          f"latency p50 {report['latency_p50_s']*1e3:.0f}ms "
          f"p95 {report['latency_p95_s']*1e3:.0f}ms, "
          f"ttft p50 {report['ttft_p50_s']*1e3:.0f}ms")
    if "kv_pool" in report:
        kv = report["kv_pool"]
        print(f"kv pool: {kv['n_pages']} pages x {kv['page_size']} tok, "
              f"peak resident {kv['peak_resident']}, "
              f"peak sharing {kv['peak_sharing_ratio']:.2f}x, "
              f"{kv['shared_page_hits']} shared hits, "
              f"{kv['cow_copies']} CoW copies")
    check_outputs(cfg, engine, requests)

    if args.check_exact:
        # Same trace, dense rows, full-precision KV: the paged/int8
        # engine must emit byte-identical greedy token streams.
        ref_pol = policy.replace(kv_layout="dense", quant_kv="off")
        ref = ServingEngine(
            cfg, params, max_slots=max_slots, max_len=max_len,
            sampler=make_sampler(args.sampler, seed=args.seed),
            policy=ref_pol)
        ref_reqs = [ref.submit(p, g, arrival_time=t, enc_frames=enc)
                    for p, g, t, enc in work]
        ref.run()
        for a, b in zip(requests, ref_reqs):
            assert a.generated == b.generated, \
                (a.rid, a.generated, b.generated)
        if "kv_pool" in report and args.prefix_len:
            assert report["kv_pool"]["peak_sharing_ratio"] > 1.0, \
                report["kv_pool"]
        print(f"check-exact: {len(requests)} token streams match the "
              f"dense reference")

    if not args.requests:
        # degenerate mode keeps the pre-engine return contract:
        # (batch, gen) int32 token grid, submission order
        gen = np.stack([np.asarray(r.generated, np.int32)
                        for r in requests])
        print("generated ids[0,:16]:", gen[0, :16].tolist())
        return gen
    return report


if __name__ == "__main__":
    main()
