import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, lower + compile the real
step function (train_step / prefill / serve_step) against the
production mesh with ShapeDtypeStruct inputs, print
memory_analysis() / cost_analysis(), and emit the roofline report
(deliverable g) into experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST run before any jax import (device count
locks on first init) — hence the unusual module header.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import SHAPES, get_shape
from repro.distributed import sharding as shard_rules
from repro.distributed.context import mesh_context
from repro.launch import mesh as mesh_lib
from repro.launch import specs as S
from repro.optim.adamw import AdamW, cosine_schedule
from repro.roofline import analysis as roofline
from repro.training import train_loop as TL


def _shardings(mesh, spec_tree):
    return shard_rules.shardings_for(mesh, spec_tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               overrides: dict | None = None, verbose: bool = True):
    """Lower + compile one cell. Returns (compiled, report)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = get_shape(shape_name)
    ok, reason = S.applicable(cfg, cell)
    if not ok:
        return None, {"arch": arch, "shape": shape_name,
                      "skipped": True, "reason": reason}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    n_dev = mesh.devices.size
    dp = n_dev // mesh.shape["model"]

    import jax.numpy as jnp
    from repro.models import model as M

    t0 = time.time()
    param_structs = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shard_rules.param_specs(param_structs, mesh)
    psh = _shardings(mesh, pspecs)

    if cell.kind == "train":
        opt = AdamW(lr=cosine_schedule(3e-4, 2000, 100_000),
                    state_dtype="float32")
        state_structs = jax.eval_shape(
            lambda p: TL.TrainState(
                params=p, opt=opt.init(p), ef=None), param_structs)
        # optimizer state shards exactly like its param (ZeRO-for-free)
        from jax.sharding import PartitionSpec as P
        from repro.optim.adamw import AdamWState
        state_specs = TL.TrainState(
            params=pspecs,
            opt=AdamWState(step=P(), m=pspecs, v=pspecs),
            ef=None)
        ssh = _shardings(mesh, state_specs)
        batch = S.train_batch_specs(cfg, cell)
        bspec = S.batch_pspec(batch, multi_pod=multi_pod, dp=dp)
        bsh = _shardings(mesh, bspec)
        step = TL.make_train_step(cfg, opt, accum=1)
        with mesh, mesh_context(mesh, multi_pod=multi_pod):
            lowered = jax.jit(
                step,
                in_shardings=(ssh, bsh),
                out_shardings=(ssh, None),
                donate_argnums=(0,),
            ).lower(state_structs, batch)
    elif cell.kind == "prefill":
        batch = S.prefill_batch_specs(cfg, cell)
        bsh = _shardings(mesh, S.batch_pspec(batch, multi_pod=multi_pod, dp=dp))
        cache = S.cache_specs_struct(cfg, cell)
        csh = _shardings(
            mesh, shard_rules.cache_specs(cache, mesh, multi_pod=multi_pod))
        fn = TL.make_prefill(cfg)
        with mesh, mesh_context(mesh, multi_pod=multi_pod):
            lowered = jax.jit(
                fn,
                in_shardings=(psh, bsh, csh),
                out_shardings=(None, csh),
                donate_argnums=(2,),
            ).lower(param_structs, batch, cache)
    else:  # decode
        token, pos, cache = S.decode_inputs(cfg, cell)
        csh = _shardings(
            mesh, shard_rules.cache_specs(cache, mesh, multi_pod=multi_pod))
        tsh = _shardings(mesh, S.batch_pspec(
            {"t": token}, multi_pod=multi_pod, dp=dp))["t"]
        fn = TL.make_serve_step(cfg)
        with mesh, mesh_context(mesh, multi_pod=multi_pod):
            lowered = jax.jit(
                fn,
                in_shardings=(psh, tsh, None, csh),
                out_shardings=(None, csh),
                donate_argnums=(3,),
            ).lower(param_structs, token, pos, cache)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops=%s bytes=%s (per-device, "
              "scan bodies counted once — see roofline for true totals)"
              % (cost.get("flops"), cost.get("bytes accessed")))

    report = roofline.build_report(
        cfg, cell, kind=cell.kind, mesh_name=mesh_name, n_devices=n_dev,
        hlo_text=compiled.as_text(), memory_analysis=mem)
    rj = report.to_json()
    rj["compile_seconds"] = t_compile
    rj["lower_seconds"] = t_lower
    rj["xla_cost_analysis"] = {k: cost.get(k) for k in ("flops",
                                                        "bytes accessed")}
    if verbose:
        print("  " + report.summary_line())
    return compiled, rj


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        tag = "multipod" if args.multi_pod else "singlepod"
        out_path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
        try:
            _, rj = lower_cell(arch, shape, multi_pod=args.multi_pod)
            with open(out_path, "w") as f:
                json.dump(rj, f, indent=2)
        except Exception:
            failures.append((arch, shape))
            traceback.print_exc()
    if failures:
        print("FAILED cells:", failures)
        sys.exit(1)
    print("dry-run complete:", len(cells), "cells")


if __name__ == "__main__":
    main()
