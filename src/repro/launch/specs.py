"""ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation: the dry-run lowers against these (weak-type
correct, shardable), exactly the shannon/kernels pattern. Modality
frontends are STUBS per spec — whisper gets precomputed frame
embeddings, qwen2-vl gets aligned patch embeddings + M-RoPE position
streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell, get_shape
from repro.models import model as M


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell):
    b, s = cell.global_batch, cell.seq_len
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["positions"] = sds((b, s, 3), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_frames"] = sds((b, cfg.enc_ctx, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return batch


def prefill_batch_specs(cfg: ModelConfig, cell: ShapeCell):
    batch = train_batch_specs(cfg, cell)
    batch.pop("labels")
    return batch


def cache_specs_struct(cfg: ModelConfig, cell: ShapeCell):
    """ShapeDtypeStructs of the serving cache at this cell's length."""
    return jax.eval_shape(
        lambda: M.init_cache(cfg, cell.global_batch, cell.seq_len))


def decode_inputs(cfg: ModelConfig, cell: ShapeCell):
    b = cell.global_batch
    token = sds((b, 1), jnp.int32)
    pos = sds((), jnp.int32)
    cache = cache_specs_struct(cfg, cell)
    return token, pos, cache


def batch_pspec(batch, *, multi_pod: bool, dp: int):
    """PartitionSpec tree for a batch dict: leading (batch) dim over DP
    when divisible."""
    axes = ("pod", "data") if multi_pod else ("data",)

    def fn(leaf):
        if leaf.shape and leaf.shape[0] % dp == 0 and leaf.shape[0] > 1:
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree.map(fn, batch)


def applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — DESIGN §6 skip table."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k-token KV decode is "
                       "quadratic-cost/OOM; skipped per spec, see DESIGN §6")
    if cell.name == "long_500k" and cfg.family == "encdec":
        return False, "enc-dec decoder caps at short contexts (DESIGN §6)"
    return True, ""
