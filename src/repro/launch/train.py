"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 200 --batch 8 --seq 128 [--ckpt-dir ckpts] \
        [--fail-at 50] [--compress] [--accum 2] [--model-parallel 1]

On this CPU container it trains the reduced configs for real (the
end-to-end example); on a TPU fleet the same driver runs the full
configs — the mesh, sharding rules, checkpointing, supervisor and data
pipeline are identical code paths.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import tuning
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_NAMES, get_config
from repro.core import policy as policy_mod
from repro.core.policy import LEGACY_BACKEND_NAMES, Policy
from repro.data.pipeline import SyntheticLM
from repro.distributed import sharding as shard_rules
from repro.distributed.context import mesh_context
from repro.distributed.fault_tolerance import (FailureInjector, Supervisor)
from repro.launch import mesh as mesh_lib
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training import train_loop as TL


def build(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    policy = Policy.from_backend(args.backend)
    policy_mod.set_default_policy(policy)
    if policy.autotune == "cached" or args.autotune:
        # Warm the autotuner cache before init/jit so tuned tiles are
        # baked into the compiled train step (both fwd and the VJP
        # GEMMs route through the same chokepoint), keyed by the
        # policy the runtime lookup will resolve to.
        rep = tuning.warm_start(
            cfg, args.batch, args.seq,
            policy=policy if policy.autotune == "cached" else None,
            autotune=args.autotune, backward=True)
        print(tuning.describe_warm_start(rep))
    mesh = mesh_lib.make_host_mesh(args.model_parallel)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                clip_norm=1.0)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       seed=args.seed)
    with mesh_context(mesh):
        state = TL.init_state(cfg, opt, jax.random.PRNGKey(args.seed),
                              compress=args.compress)
    pspecs = shard_rules.param_specs(state.params, mesh)
    step_fn = jax.jit(TL.make_train_step(cfg, opt, accum=args.accum,
                                         compress=args.compress),
                      donate_argnums=(0,))
    return cfg, mesh, state, step_fn, data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    help=f"one of {ARCH_NAMES} or a registered custom config")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--backend", choices=LEGACY_BACKEND_NAMES, default="xla",
                    help="GEMM backend for every dense contraction; "
                         "constructs the run's execution Policy "
                         "(tuned = pallas with autotuner-cached tiles)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune uncached GEMM shapes at startup")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=(),
                    help="inject simulated failures at these steps")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh, state, step_fn, data = build(args)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"devices={len(jax.devices())} mesh={dict(mesh.shape)}")

    losses = []

    def on_metrics(step, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")

    def run_step(state, step):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        with mesh_context(mesh):
            return step_fn(state, batch)

    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3)
        sup = Supervisor(ckpt, checkpoint_every=args.ckpt_every)
        injector = FailureInjector(tuple(args.fail_at)) if args.fail_at else None
        start = ckpt.latest_step() or 0
        if start:
            state = ckpt.restore(start, state)
            print(f"resumed from checkpoint step {start}")
        t0 = time.time()
        state, step = sup.run_resilient(
            state, run_step, args.steps, start_step=start,
            injector=injector, on_metrics=on_metrics)
        print(f"done at step {step} in {time.time()-t0:.1f}s "
              f"(restarts={sup.restarts}, "
              f"stragglers={len(sup.straggler.flagged)})")
    else:
        t0 = time.time()
        for step in range(args.steps):
            t1 = time.perf_counter()
            state, metrics = run_step(state, step)
            on_metrics(step, metrics, time.perf_counter() - t1)
        print(f"done {args.steps} steps in {time.time()-t0:.1f}s")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
