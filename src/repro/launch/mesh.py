"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests and
benches see the real 1-CPU world).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType

    def axis_kw(n: int) -> dict:
        """kwargs for jax.make_mesh: n Auto axes (compat shim — older
        jax has no AxisType and Auto is the only behaviour)."""
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    def axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_kw(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), **axis_kw(2))


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
