"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests and
benches see the real 1-CPU world).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
