"""train_step / serve_step factories.

train_step(params, opt_state, ef_state, batch) — value_and_grad over
the model loss with:
  * gradient accumulation: the global batch is split into `accum`
    microbatches scanned sequentially (activation memory / batch-size
    decoupling — how train_4k x batch-256 fits);
  * optional int8 error-feedback gradient compression before the
    (pjit-inserted) data-parallel reduction;
  * AdamW with global-norm clipping, cosine schedule;
  * donated params/opt_state (in launch/train.py's jit wrapper).

serve_step(params, token, pos, cache) — one decode token; prefill()
builds the cache. Both are what launch/dryrun.py lowers. `pos` is a
scalar for the lock-step single-batch path, or a (B,) per-slot vector
for the continuous-batching engine (repro.serving): each row of the
batch is an independent request at its own depth, pos < 0 marks an
inactive slot. One jitted serve_step serves both shapes.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import policy as _pol
from repro.distributed import compression as comp
from repro.models import model as M
from repro.optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[comp.EFState]


def init_state(cfg, optimizer: AdamW, key, *, compress: bool = False):
    params = M.init_params(cfg, key)
    return TrainState(
        params=params,
        opt=optimizer.init(params),
        ef=comp.init_ef(params) if compress else None,
    )


def _split_microbatches(batch, accum: int):
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg, optimizer: AdamW, *, accum: int = 1,
                    compress: bool = False, policy=None):
    """`policy` (default: the ambient core.policy default at factory
    time) is pinned into the returned step: the function body enters
    policy.scope() during tracing, so every GEMM the model and its VJP
    emit — across retraces — executes under the same policy."""
    policy = _pol.resolve(policy)

    def loss_fn(params, mb):
        return M.loss_fn(cfg, params, mb)

    def _train_step(state: TrainState, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            mbs = _split_microbatches(batch, accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), met

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), mets = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = jax.tree.map(lambda m: m[-1], mets)
            metrics["ce_loss"] = loss

        ef = state.ef
        if compress and ef is not None:
            grads, ef = comp.compress_grads(grads, ef)

        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        return TrainState(params, opt, ef), metrics

    def train_step(state: TrainState, batch):
        with policy.scope():            # trace-time: pins the policy
            return _train_step(state, batch)

    return train_step


def make_serve_step(cfg, *, policy=None):
    policy = _pol.resolve(policy)

    def serve_step(params, token, pos, cache):
        # pos: scalar (uniform batch) or (B,) int32 per-slot vector —
        # threaded straight through to the per-slot cache writes.
        with policy.scope():            # trace-time: pins the policy
            return M.decode_step(cfg, params, token, pos, cache)
    return serve_step


def make_prefill(cfg, *, policy=None):
    policy = _pol.resolve(policy)

    def prefill_fn(params, batch, cache):
        with policy.scope():            # trace-time: pins the policy
            return M.prefill(cfg, params, batch, cache)
    return prefill_fn


def make_verify_step(cfg, *, policy=None):
    """Speculative-verification step under a pinned policy: all k+1
    pending+draft tokens per slot in ONE prefill-shaped forward (see
    model.verify_step). The serving engine jits this with the cache
    donated, same as its serve_step."""
    policy = _pol.resolve(policy)

    def verify_step(params, tokens, pos, n_tok, cache):
        with policy.scope():            # trace-time: pins the policy
            return M.verify_step(cfg, params, tokens, pos, n_tok, cache)
    return verify_step
