"""Public facade for the reproduction.

One import surface for the stable API:

    import repro

    # typed execution policy (core.policy) — THE way to pick kernels
    pol = repro.Policy(backend="pallas", autotune="cached")
    with pol.scope():
        y = repro.matmul(a, b)                  # GEMM chokepoint
        h = repro.gated_mlp(x, wg, wu)          # dual-GEMM SwiGLU
    o = repro.flash_attention(q, k, v, policy=pol)

    engine = repro.ServingEngine(cfg, params, max_slots=4,
                                 max_len=256, policy=pol)
    repro.warm_start(cfg, batch, seq, policy=pol)

Everything in `__all__` is covenanted: tests/test_api_surface.py pins
the list against a checked-in snapshot so an API break is an explicit
diff, and CI runs examples/quickstart.py against exactly this surface.
Deep imports (repro.core.gemm, repro.kernels.ops, ...) keep working but
are not part of the covenant.

Submodules are imported lazily: `import repro` itself stays light (no
jax) until a symbol is touched.
"""

from __future__ import annotations

from repro.core.policy import (LEGACY_BACKEND_NAMES, Policy, current_policy,
                               set_default_policy)

__version__ = "0.1.0"

#: name -> (module, attribute) for the lazily-bound part of the facade.
_EXPORTS = {
    # GEMM chokepoint (core.gemm)
    "matmul": ("repro.core.gemm", "matmul"),
    "dense": ("repro.core.gemm", "dense"),
    "dense_q": ("repro.core.gemm", "dense_q"),
    "gated_mlp": ("repro.core.gemm", "gated_mlp"),
    # weight quantization (core.precision / models)
    "QuantSpec": ("repro.core.precision", "QuantSpec"),
    "quantize_int8": ("repro.core.precision", "quantize_int8"),
    "dequantize": ("repro.core.precision", "dequantize"),
    "quantize_params": ("repro.models.model", "quantize_params"),
    # kernel-level ops (kernels.ops)
    "flash_attention": ("repro.kernels.ops", "flash_attention"),
    "flash_attention_bwd": ("repro.kernels.ops", "flash_attention_bwd"),
    "flash_decode": ("repro.kernels.ops", "flash_decode"),
    "flash_decode_paged": ("repro.kernels.ops", "flash_decode_paged"),
    "ssd": ("repro.kernels.ops", "ssd"),
    "add": ("repro.kernels.ops", "add"),
    "sub": ("repro.kernels.ops", "sub"),
    # kernel registry (kernels.registry)
    "register_op": ("repro.kernels.registry", "register_op"),
    "registered_ops": ("repro.kernels.registry", "registered_ops"),
    "registered_backends": ("repro.kernels.registry", "registered_backends"),
    # model configs
    "get_config": ("repro.configs", "get_config"),
    "ARCH_NAMES": ("repro.configs", "ARCH_NAMES"),
    # serving
    "ServingEngine": ("repro.serving", "ServingEngine"),
    "Request": ("repro.serving", "Request"),
    "KVPagePool": ("repro.serving", "KVPagePool"),
    "KVPoolExhausted": ("repro.serving", "KVPoolExhausted"),
    "make_sampler": ("repro.serving", "make_sampler"),
    "synthetic_trace": ("repro.serving", "synthetic_trace"),
    "prefix_heavy_trace": ("repro.serving", "prefix_heavy_trace"),
    "bursty_trace": ("repro.serving", "bursty_trace"),
    "long_context_trace": ("repro.serving", "long_context_trace"),
    "make_trace": ("repro.serving", "make_trace"),
    # speculative decoding (serving.spec)
    "SpecDecoder": ("repro.serving", "SpecDecoder"),
    # fault tolerance (serving.faults)
    "FaultInjector": ("repro.serving", "FaultInjector"),
    "SimulatedKernelFault": ("repro.serving", "SimulatedKernelFault"),
    # tuning
    "TuningCache": ("repro.tuning", "TuningCache"),
    "tune_matmul": ("repro.tuning", "tune_matmul"),
    "tune_gated_matmul": ("repro.tuning", "tune_gated_matmul"),
    "tune_flash_attention": ("repro.tuning", "tune_flash_attention"),
    "tune_flash_bwd": ("repro.tuning", "tune_flash_bwd"),
    "tune_flash_decode": ("repro.tuning", "tune_flash_decode"),
    "tune_flash_decode_paged": ("repro.tuning", "tune_flash_decode_paged"),
    "tune_ssd": ("repro.tuning", "tune_ssd"),
    "warm_start": ("repro.tuning", "warm_start"),
    "default_exec_policy": ("repro.tuning", "default_exec_policy"),
    # deprecation shims (string-backend era; warn once per process)
    "set_default_backend": ("repro.core.gemm", "set_default_backend"),
    "use_backend": ("repro.core.gemm", "use_backend"),
}

__all__ = sorted([
    "Policy", "current_policy", "set_default_policy",
    "LEGACY_BACKEND_NAMES", "__version__", *_EXPORTS,
])


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value      # cache: subsequent lookups skip this
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
