"""Logical activation-sharding constraints.

Model code calls `constrain(x, "dp", None, "tp", None)` at layer
boundaries; when a mesh context is active (the launcher/dry-run), this
becomes jax.lax.with_sharding_constraint with the logical axes mapped
onto the physical mesh — pinning the batch to the data axis and heads /
expert / channel dims to the model axis so the SPMD partitioner never
falls back to replication (the 204 GiB/device failure mode recorded in
EXPERIMENTS §Dry-run). With no context (CPU tests, examples) it is a
no-op.

Logical axes: "dp" -> ("pod","data") | ("data",)   batch-like dims
              "tp" -> "model"                       head/channel dims
Axes that do not divide the dim size are dropped (replicated) rather
than erroring — MQA heads, batch-1 long-context, 8-expert banks.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _multi_pod() -> bool:
    return getattr(_state, "multi_pod", False)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, *, multi_pod: bool = False):
    prev = (current_mesh(), _multi_pod())
    _state.mesh, _state.multi_pod = mesh, multi_pod
    try:
        yield
    finally:
        _state.mesh, _state.multi_pod = prev


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return math.prod(mesh.shape[n] for n in name)
    return mesh.shape[name]


def constrain(x, *logical):
    """logical per dim: "dp" / "tp" (pin to that mesh axis), None (pin
    to REPLICATED — a demand, not a default), or "free"
    (P.UNCONSTRAINED — let the partitioner choose; use for dims like a
    40-head axis that XLA can factor 8x2 on a 16-wide mesh axis, where
    forcing replication triggers involuntary-remat copies; measured in
    EXPERIMENTS §Perf it1)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    dp = ("pod", "data") if _multi_pod() else ("data",)
    parts = []
    for dim, l in zip(x.shape, logical):
        if l is None:
            parts.append(None)
            continue
        if l == "free":
            parts.append(P.UNCONSTRAINED)
            continue
        phys = dp if l == "dp" else "model"
        if dim % _axis_size(mesh, phys) == 0:
            parts.append(phys)
        else:
            parts.append(P.UNCONSTRAINED)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
