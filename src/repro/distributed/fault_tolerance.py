"""Fault tolerance: supervised training, straggler detection, elastic
re-meshing.

On a real 1000+-node fleet the failure modes are: worker crash (host or
chip), hung collective (straggler turned zombie), and capacity loss
(pod down => smaller mesh). The mechanisms here map 1:1:

  * Supervisor.run_resilient — step-scoped try/except; on failure,
    restore latest checkpoint and continue; bounded restarts.
  * StragglerDetector — per-step EWMA; steps slower than
    `threshold x EWMA` are flagged (on TPU fleets, the signal feeding
    hot-swap / re-scheduling decisions).
  * elastic_mesh_shape — given the surviving chip count, pick the
    largest (data, model) mesh that keeps the model axis intact; the
    checkpoint's logical specs re-lay params onto it (checkpointer).
  * FailureInjector — deterministic simulated failures for tests and
    the resilience example.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fail_once: bool = True

    def __post_init__(self):
        self._fired = set()

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            if self.fail_once:
                self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:        # compile steps excluded
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


def elastic_mesh_shape(n_devices: int, model_parallel: int) -> tuple:
    """Largest (data, model) grid for the surviving devices, keeping the
    model axis intact (TP groups cannot shrink without resharding the
    layer math)."""
    assert n_devices >= model_parallel, (n_devices, model_parallel)
    data = n_devices // model_parallel
    return (data, model_parallel)


class Supervisor:
    """Wraps a step function with checkpoint-restart semantics."""

    def __init__(self, checkpointer, *, max_restarts: int = 3,
                 checkpoint_every: int = 50):
        self.ckpt = checkpointer
        self.max_restarts = max_restarts
        self.checkpoint_every = checkpoint_every
        self.restarts = 0
        self.straggler = StragglerDetector()

    def run_resilient(
        self,
        state,                                    # (params, opt_state, ...)
        step_fn: Callable,                        # (state, step) -> state, metrics
        n_steps: int,
        *,
        start_step: int = 0,
        injector: Optional[FailureInjector] = None,
        on_metrics: Optional[Callable] = None,
        spec=None,
    ):
        step = start_step
        while step < n_steps:
            try:
                if injector is not None:
                    injector.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, step)
                dt = time.perf_counter() - t0
                self.straggler.observe(step, dt)
                if on_metrics is not None:
                    on_metrics(step, metrics, dt)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state, spec=spec, blocking=False)
            except Exception as e:   # noqa: BLE001 — supervisor boundary
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                # quiesce the async writer FIRST — an in-flight save must
                # become visible before we look for the latest step
                # (regression-tested: test_supervisor_recovers_...)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    continue   # no checkpoint yet: retry step with live state
                state = self.ckpt.restore(latest, state)
                step = latest
        self.ckpt.wait()
        return state, step
