"""Gradient compression: int8 quantisation with error feedback.

Before the data-parallel all-reduce, gradients are quantised to int8
with a per-tensor scale; the quantisation error is kept in a local
buffer and added to the *next* step's gradient (error feedback /
EF-SGD), which restores convergence to the uncompressed path in
expectation. 4x fewer ICI bytes on the gradient all-reduce — one of
the §Perf levers for collective-bound cells.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: Any         # same structure/dtype as grads (f32)


def init_ef(params) -> EFState:
    return EFState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState):
    """Returns (quantised-representable grads, new EF state).

    The returned grads are exactly what the receiving side would
    dequantise, so the training step can all-reduce them (or, under
    pjit, simply use them — XLA reduces the int-representable values
    identically) while the residual stays local.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(error=new_e)


def compression_ratio(params, bits: int = 8) -> float:
    total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    comp = sum(x.size * bits // 8 + 4 for x in jax.tree.leaves(params))
    return total / comp
