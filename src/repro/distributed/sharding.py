"""Sharding rules: param-path regex -> PartitionSpec.

The scheme (DESIGN §5): TP over "model", FSDP over "data", DP over
("pod", "data") for activations. Expert banks get EP over "model".
Scanned stacks carry a leading layer dim that is never sharded.

These rules are *logical*: the same table drives the 16x16 single-pod
mesh, the 2x16x16 multi-pod mesh, and any elastic re-mesh — only the
mesh object changes.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex over "/"-joined param path, CANDIDATE specs in priority order,
# WITHOUT the scan-layer dim). The first candidate whose named axes all
# divide the corresponding dim is used; as a last resort failing axes
# are dropped (replicated). First regex match wins.
_RULES = (
    # MoE expert banks (E, D, F) / (E, F, D): EP on E + FSDP on middle;
    # when E < |model| (Mixtral 8e on a 16-wide axis) fall back to
    # TP+FSDP inside each expert.
    (r"moe/w_(gate|up)$",        (P("model", "data", None),
                                  P(None, "data", "model"))),
    (r"moe/w_down$",             (P("model", "data", None),
                                  P(None, "model", "data"))),
    (r"moe/router/w$",           (P("data", None),)),
    # attention projections
    (r"attn/w[qkv]/w$",          (P("data", "model"),)),
    (r"attn/w[qkv]/b$",          (P("model"),)),
    (r"attn/wo/w$",              (P("model", "data"),)),
    (r"attn/wo/b$",              (P(None),)),
    (r"cross_attn/w[qkv]/w$",    (P("data", "model"),)),
    (r"cross_attn/w[qkv]/b$",    (P("model"),)),
    (r"cross_attn/wo/w$",        (P("model", "data"),)),
    (r"cross_attn/wo/b$",        (P(None),)),
    # MLPs
    (r"(mlp|dense)/w_(gate|up|in)/w$",  (P("data", "model"),)),
    (r"(mlp|dense)/w_(down|out)/w$",    (P("model", "data"),)),
    (r"(mlp|dense)/w_(gate|up|in)/b$",  (P("model"),)),
    (r"(mlp|dense)/w_(down|out)/b$",    (P(None),)),
    # Mamba2
    (r"mamba/in_proj/w$",        (P("data", "model"),)),
    # B/C/dt projection + conv: replicated output (tiny; avoids the
    # per-layer broadcast of stranded state channels — §Perf mamba2 it4)
    (r"mamba/in_proj_bc/w$",     (P("data", None),)),
    (r"mamba/conv_bc_w$",        (P(None, None),)),
    (r"mamba/conv_bc_b$",        (P(None),)),
    (r"mamba/out_proj/w$",       (P("model", "data"),)),
    (r"mamba/conv_w$",           (P(None, "model"),)),
    (r"mamba/conv_b$",           (P("model"),)),
    (r"mamba/(A_log|D|dt_bias)$", (P("model"),)),
    (r"mamba/norm/scale$",       (P("model"),)),
    # Zamba2 shared block extras
    (r"shared/in_proj/w$",       (P("data", "model"),)),
    (r"lora_a$",                 (P(None, "data", None),)),
    (r"lora_b$",                 (P(None, None, "model"),)),
    # embeddings / head (vocab is padded to 256 so these divide)
    (r"embed/w$",                (P("model", "data"),)),
    (r"lm_head/w$",              (P("data", "model"),)),
    # norms and anything 1-D
    (r".*",                      (P(),)),
)

# param paths that carry leading stacked-layer dims (scan): the spec is
# shifted right by the number of stack dims.
_STACK1 = re.compile(r"^(layers|enc_layers|dec_layers)/|^hybrid/(shared_conv)?")
_STACK2 = re.compile(r"^hybrid/mamba/")
_STACK1_HYBRID = re.compile(r"^hybrid/(lora_a|lora_b)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _stack_dims(path_s: str) -> int:
    if _STACK2.match(path_s):
        return 2                       # (n_seg, per_seg, ...)
    if _STACK1_HYBRID.match(path_s):
        return 1                       # (n_seg, ...)
    if path_s.startswith("hybrid/shared"):
        return 0
    if _STACK1.match(path_s):
        return 1
    return 0


def _axis_size(mesh: Optional[Mesh], name) -> int:
    if mesh is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _fits(spec_parts, shape, mesh) -> bool:
    for part, dim in zip(spec_parts, shape):
        if part is None:
            continue
        if dim % _axis_size(mesh, part) != 0:
            return False
    return True


def spec_for(path_s: str, shape: tuple, mesh: Optional[Mesh] = None) -> P:
    ndim = len(shape)
    stack = _stack_dims(path_s)
    candidates = (P(),)
    for pat, specs in _RULES:
        if re.search(pat, path_s):
            candidates = specs
            break

    def expand(spec) -> list:
        parts = ([None] * stack) + list(spec)
        if len(parts) > ndim:          # e.g. biases matched to 2D rule
            parts = parts[:ndim]
        parts += [None] * (ndim - len(parts))
        return parts

    for spec in candidates:
        parts = expand(spec)
        if _fits(parts, shape, mesh):
            return P(*parts)
    # last resort: drop failing axes (replicate those dims)
    parts = expand(candidates[-1])
    parts = [p if p is not None and shape[i] % _axis_size(mesh, p) == 0
             else None for i, p in enumerate(parts)]
    return P(*parts)


def param_specs(params, mesh: Optional[Mesh] = None) -> Any:
    """Pytree of PartitionSpec matching `params` (shape/mesh aware)."""
    def fn(path, leaf):
        return spec_for(_path_str(path), tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(fn, params)


def data_spec(ndim: int, *, multi_pod: bool) -> P:
    dp = ("pod", "data") if multi_pod else ("data",)
    return P(dp, *([None] * (ndim - 1)))


def cache_specs(cache, mesh: Mesh, *, multi_pod: bool) -> Any:
    """KV / SSM state caches: batch over DP when divisible, kv-heads /
    SSD-heads / conv channels over "model" when divisible (MQA and
    batch-1 long-context leaves fall back to replication — recorded in
    EXPERIMENTS.md as a hillclimb lever)."""
    import math
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    tp = mesh.shape["model"]

    def fn(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        parts: list = [None] * nd
        key = ps.rsplit("/", 1)[-1]
        if key in ("k", "v"):          # (*stack, B, T, H, D)
            b_ax, h_ax = nd - 4, nd - 2
        elif key == "ssd":             # (*stack, B, H, P, N)
            b_ax, h_ax = nd - 4, nd - 3
        elif key == "conv":            # (*stack, B, W-1, C)
            b_ax, h_ax = nd - 3, nd - 1
        else:
            return P(*parts)
        if leaf.shape[b_ax] % dp == 0:
            parts[b_ax] = dp_axes
        if leaf.shape[h_ax] % tp == 0:
            parts[h_ax] = "model"
        return P(*parts)
    return jax.tree_util.tree_map_with_path(fn, cache)


def shardings_for(mesh: Mesh, specs) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
