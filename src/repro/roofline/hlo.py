"""Static analyzer over optimized HLO text.

Why not compiled.cost_analysis()? XLA's HloCostAnalysis visits a while
body ONCE — a 56-layer scanned transformer reports 1/56th of its flops
(verified; see EXPERIMENTS.md §Dry-run notes). Since scan-over-layers
is non-negotiable at 512 devices, this module re-derives costs from
`compiled.as_text()` with while-loop trip counts applied:

  flops        — 2 * prod(result_dims) * prod(contracting_dims) per
                 dot / custom-call matmul; elementwise ignored (<1%).
  hbm bytes    — per top-level instruction: operand + output bytes
                 (the same model XLA uses on fused modules).
  collectives  — per op kind: result bytes, replica-group size, and the
                 ring-model ICI bytes; counted with loop multipliers.

It is deliberately conservative and fully transparent — the §Perf
iterations read these numbers.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_RHS_RE = re.compile(
    r"^(\([^()]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]*n["}: ]*"?(\d+)')


def _parse_shape(text: str):
    """-> list of (dtype, dims) for every shape literal in `text`."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims_t = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, dims_t))
    return out


def _shape_bytes(shapes) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or (1,))
               for dt, dims in shapes)


def _shape_elems(shapes) -> int:
    return sum(math.prod(dims or (1,)) for dt, dims in shapes)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_shapes: list
    rest: str                  # operand list + attributes


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


@dataclasses.dataclass
class Collective:
    op: str
    bytes_result: int
    group_size: int
    count: int                 # loop-scaled invocation count
    where: str

    @property
    def ici_bytes(self) -> float:
        """Ring-model bytes crossing ICI per device, per invocation."""
        p, n = self.group_size, self.bytes_result
        if p <= 1:
            return 0.0
        if self.op.startswith("all-reduce"):
            return 2 * n * (p - 1) / p
        if self.op.startswith("all-gather"):
            return n * (p - 1) / p
        if self.op.startswith("reduce-scatter"):
            return n * (p - 1)          # operand = result * p
        if self.op.startswith("all-to-all"):
            return n * (p - 1) / p
        if self.op.startswith("collective-permute"):
            return n
        return n


def _group_size(rest: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]*)\}", rest)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    m = re.search(r"replica_groups=\{\}", rest)
    if m:
        return total_devices
    return total_devices


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and "{" in line:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        m2 = _RHS_RE.match(rhs)
        if not m2:
            continue
        shape_txt, op, rest = m2.groups()
        cur.instrs.append(Instr(name, op, _parse_shape(shape_txt), rest))
    return comps


_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # loop-carry copies are aliased/elided on TPU; charging them models
    # the CPU backend, not the target (documented choice).
    "copy", "copy-start", "copy-done",
}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[Collective] = dataclasses.field(default_factory=list)
    charges: List[tuple] = dataclasses.field(default_factory=list)
    # hbm bytes attributed to named_scope tags ("flashsite", "ssdsite")
    tagged_bytes: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for c in other.collectives:
            self.collectives.append(dataclasses.replace(
                c, count=int(c.count * mult)))
        for (b, desc) in other.charges:
            self.charges.append((b * mult, desc))
        for t, b in other.tagged_bytes.items():
            self.tagged_bytes[t] = self.tagged_bytes.get(t, 0.0) + b * mult

    def top_charges(self, n: int = 15):
        return sorted(self.charges, reverse=True)[:n]

    @property
    def ici_bytes(self) -> float:
        return sum(c.ici_bytes * c.count for c in self.collectives)

    def collective_summary(self) -> dict:
        agg = defaultdict(lambda: {"count": 0, "bytes": 0.0, "ici_bytes": 0.0})
        for c in self.collectives:
            base = c.op.replace("-start", "")
            agg[base]["count"] += c.count
            agg[base]["bytes"] += c.bytes_result * c.count
            agg[base]["ici_bytes"] += c.ici_bytes * c.count
        return dict(agg)


def _dot_flops(instr: Instr, symbols: dict) -> float:
    result_elems = _shape_elems(instr.result_shapes)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not m:
        return 2.0 * result_elems      # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    # first operand name; XLA prints operands typed ("f32[64,128]{1,0}
    # %lhs") or bare ("%lhs") depending on version — skip the shape.
    om = re.match(
        r"\s*(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?\s*)?%?([\w.\-]+)",
        instr.rest)
    contract = 1
    if om and om.group(1) in symbols:
        lhs_shapes = symbols[om.group(1)]
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for c in cdims:
                if c < len(dims):
                    contract *= dims[c]
    return 2.0 * result_elems * contract


_TAGS = ("flashsite", "ssdsite")


def _tag_of(rest: str):
    for t in _TAGS:
        if t in rest:
            return t
    return None


def _operand_names(rest: str) -> List[str]:
    # operands are everything up to the matching ')': take names before
    # first "), " attribute boundary — robust enough for optimized HLO.
    depth, out, cur = 1, [], ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur += ch
    for tok in cur.split(","):
        tok = tok.strip().lstrip("%")
        if tok and re.match(r"^[\w.\-]+$", tok):
            out.append(tok)
    return out


def _fusion_in_bytes(instr: Instr, symbols: dict, callee) -> float:
    """Operand read-bytes of a fusion, slice-aware: a fusion parameter
    consumed by a dynamic-slice inside the callee reads only the slice
    (e.g. the bwd loop slicing layer i's activations out of the stacked
    (L, ...) remat buffer — charging the whole buffer per iteration
    over-counted 64x on 64-layer stacks; see EXPERIMENTS §Dry-run)."""
    ops_ = _operand_names(instr.rest)
    param_by_idx = {}
    for ci in callee.instrs:
        if ci.op == "parameter":
            m = re.match(r"\s*(\d+)", ci.rest)
            if m:
                param_by_idx[int(m.group(1))] = ci.name
    ds_use: dict = {}
    for ci in callee.instrs:
        if ci.op == "dynamic-slice":
            srcs = _operand_names(ci.rest)
            if srcs:
                ds_use[srcs[0]] = (ds_use.get(srcs[0], 0)
                                   + _shape_bytes(ci.result_shapes))
    total = 0.0
    for idx, oname in enumerate(ops_):
        pbytes = _shape_bytes(symbols.get(oname, []))
        pname = param_by_idx.get(idx)
        if pname is not None and pname in ds_use:
            total += min(pbytes, 2 * ds_use[pname])
        else:
            total += pbytes
    return total


def analyze(text: str, total_devices: int,
            trip_counts: Optional[Dict[str, int]] = None) -> Costs:
    """Whole-module costs with while-loop multipliers applied."""
    comps = parse_module(text)

    # trip counts: prefer explicit backend annotation, else parse the
    # loop-condition constant, else 1 (documented undercount).
    def find_trip(instr: Instr) -> int:
        m = _TRIP_RE.search(instr.rest)
        if m:
            return int(m.group(1))
        mb = re.search(r"condition=%?([\w.\-]+)", instr.rest)
        if mb and mb.group(1) in comps:
            cond = comps[mb.group(1)]
            consts = []
            for ci in cond.instrs:
                mc = re.match(r".*constant\((\d+)\)", "%s(%s" % (ci.op, ci.rest)) \
                    if ci.op == "constant" else None
                if ci.op == "constant":
                    mc = re.match(r"^\s*(\d+)\s*\)?", ci.rest)
                    if mc:
                        consts.append(int(mc.group(1)))
            if consts:
                return max(consts)
        return 1

    memo: Dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        total = Costs()
        comp = comps.get(name)
        if comp is None:
            memo[name] = total
            return total
        symbols = {i.name: i.result_shapes for i in comp.instrs}
        for instr in comp.instrs:
            if instr.op in _ZERO_COST:
                continue
            if instr.op == "while":
                trips = find_trip(instr)
                mbody = re.search(r"body=%?([\w.\-]+)", instr.rest)
                if mbody:
                    total.add(comp_cost(mbody.group(1)), trips)
                continue
            if instr.op in ("call", "async-start"):
                mcal = re.search(r"(?:to_apply|called_computation)=%?([\w.\-]+)",
                                 instr.rest)
                if mcal:
                    total.add(comp_cost(mcal.group(1)))
                continue
            if instr.op == "conditional":
                for mbr in re.finditer(
                        r"(?:true_computation|false_computation|branch_computations=\{)[%\w.,\- ]*",
                        instr.rest):
                    pass  # conservative: take max branch below
                branches = re.findall(r"%([\w.\-]+)", instr.rest)
                sub = [comp_cost(b) for b in branches if b in comps]
                if sub:
                    best = max(sub, key=lambda c: c.flops + c.hbm_bytes)
                    total.add(best)
                continue
            base_op = instr.op.replace("-start", "")
            if base_op in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"):
                if instr.op.endswith("-done"):
                    continue
                total.collectives.append(Collective(
                    op=instr.op,
                    bytes_result=_shape_bytes(instr.result_shapes),
                    group_size=_group_size(instr.rest, total_devices),
                    count=1,
                    where=name,
                ))
                total.hbm_bytes += 2 * _shape_bytes(instr.result_shapes)
                continue
            if instr.op in ("dot", "custom-call"):
                if instr.op == "dot" or "matmul" in instr.rest:
                    total.flops += _dot_flops(instr, symbols)

            out_bytes = _shape_bytes(instr.result_shapes)
            tag = _tag_of(instr.rest)

            def _charge(nbytes):
                total.hbm_bytes += nbytes
                total.charges.append((nbytes, f"{name}/{instr.op}/{instr.name}"))
                if tag:
                    total.tagged_bytes[tag] = \
                        total.tagged_bytes.get(tag, 0.0) + nbytes

            if instr.op in ("dynamic-slice", "gather"):
                # reads only the slice it produces (+ tiny indices)
                _charge(2 * out_bytes)
                continue
            if instr.op == "dynamic-update-slice":
                ops_ = _operand_names(instr.rest)
                upd = _shape_bytes(symbols.get(ops_[1], [])) if len(ops_) > 1 \
                    else out_bytes
                _charge(2 * upd)                # in-place on TPU
                continue
            if instr.op == "fusion":
                mcal = re.search(r"calls=%?([\w.\-]+)", instr.rest)
                callee = comps.get(mcal.group(1)) if mcal else None
                if mcal:
                    # dots inside fusions still cost flops
                    total.flops += comp_cost(mcal.group(1)).flops
                if callee is not None and callee.instrs and \
                        callee.instrs[-1].op == "dynamic-update-slice":
                    # in-place DUS fusion: charge the update slice, not
                    # the whole aliased buffer.
                    root = callee.instrs[-1]
                    csym = {i.name: i.result_shapes for i in callee.instrs}
                    ops_ = _operand_names(root.rest)
                    upd = (_shape_bytes(csym.get(ops_[1], []))
                           if len(ops_) > 1 else 0)
                    in_bytes = sum(
                        _shape_bytes(symbols.get(o, []))
                        for o in _operand_names(instr.rest)
                        if _shape_bytes(symbols.get(o, [])) != out_bytes)
                    _charge(upd * 2 + min(in_bytes, _fusion_in_bytes(
                        instr, symbols, callee)))
                    continue
                if callee is not None:
                    _charge(out_bytes + _fusion_in_bytes(instr, symbols,
                                                         callee))
                    continue
            in_bytes = sum(_shape_bytes(symbols.get(o, []))
                           for o in _operand_names(instr.rest))
            _charge(out_bytes + in_bytes)
        memo[name] = total
        return total

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: computation with the most instructions
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    return comp_cost(entry)
