"""Roofline terms per (arch x shape x mesh) from a compiled dry-run.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = ICI_bytes_per_device / link_bw

HLO_FLOPs / bytes / collective bytes come from roofline.hlo (the
while-loop-aware static analyzer; compiled.cost_analysis() undercounts
scanned stacks — verified, see EXPERIMENTS §Dry-run). MODEL_FLOPS is
the 6·N·D / 2·N·D convention (N = active params for MoE), so the
MODEL_FLOPS/HLO_FLOPs ratio exposes remat and redundant compute.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax

from repro.core import blocking, hw
from repro.roofline import hlo as H


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts via eval_shape (no allocation)."""
    from repro.models import model as M

    shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    expert_total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if "moe/w_" in pstr:
            expert_total += n
    active = total
    if cfg.moe is not None and expert_total:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - expert_total + int(expert_total * frac)
    return total, active


def model_flops(cfg, cell, *, kind: str) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (one decode step),
    N = active params (MoE), D = tokens processed. Attention flops
    excluded by convention (noted in EXPERIMENTS)."""
    _, active = count_params(cfg)
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * cell.global_batch         # decode: one token/seq


# ----------------------------------------------------------------------
# Fused-kernel HBM accounting (EXPERIMENTS §HBM-traffic accounting)
#
# The fused-epilogue / dual-GEMM wins are bandwidth wins, so they are
# assertable on this CPU-only container from the same static traffic
# models the Fig.-8 reproduction uses (core.blocking) — no TPU needed.
# ----------------------------------------------------------------------

def epilogue_traffic_bytes(m: int, n: int, k: int, itemsize: int,
                           epilogue: str, cfg=None,
                           chip: hw.ChipSpec = hw.DEFAULT_CHIP,
                           fused: bool = True) -> int:
    """HBM bytes for one GEMM + epilogue (bias/activation/residual).

    Unfused, the epilogue is a separate elementwise pass: the (m, n)
    GEMM result is written, re-read together with the epilogue operand,
    and written again. Fused, the epilogue runs in the kernel's flush on
    the VMEM accumulator: only the operand read is added — the (m, n)
    intermediate never round-trips, saving 2*m*n*itemsize bytes.
    """
    if cfg is None:
        cfg = blocking.choose_block_config(m, n, k, itemsize, chip=chip)
    total = blocking.hbm_traffic_bytes(m, n, k, cfg, itemsize)
    if epilogue == "none":
        return total
    operand = m * n * itemsize if epilogue == "residual" else n * itemsize
    total += operand
    if not fused:
        total += 2 * m * n * itemsize   # write + re-read the intermediate
    return total


def gated_mlp_traffic(m: int, d_model: int, d_ff: int, itemsize: int,
                      *, fused: bool,
                      chip: hw.ChipSpec = hw.DEFAULT_CHIP,
                      cfg_hidden=None, cfg_down=None) -> dict:
    """HBM bytes for one SwiGLU MLP call, fused vs unfused.

    Unfused (the XLA composition): two tiled GEMMs each write their
    (m, d_ff) result, and the gate product reads both and writes a
    third — three full (m, d_ff) round-trips beyond the fused path.
    Fused (kernels.matmul.gated_matmul_tiled): one A stream feeds both
    weight operands and only the gated product is written
    (core.blocking.gated_traffic_bytes). The down-projection GEMM is
    identical in both and included so the ratio is per MLP *call*.
    """
    if cfg_hidden is None:
        cfg_hidden = blocking.choose_block_config(
            m, d_ff, d_model, itemsize, chip=chip, n_rhs=2 if fused else 1)
    if cfg_down is None:
        cfg_down = blocking.choose_block_config(
            m, d_model, d_ff, itemsize, chip=chip)
    if fused:
        hidden = blocking.gated_traffic_bytes(
            m, d_ff, d_model, cfg_hidden, itemsize)
    else:
        one = blocking.hbm_traffic_bytes(m, d_ff, d_model, cfg_hidden,
                                         itemsize)
        ew = 3 * m * d_ff * itemsize    # read gate, read up, write product
        hidden = 2 * one + ew
    down = blocking.hbm_traffic_bytes(m, d_model, d_ff, cfg_down, itemsize)
    return {
        "hidden_bytes": hidden,
        "down_bytes": down,
        "total_bytes": hidden + down,
        "cfg_hidden": cfg_hidden,
        "cfg_down": cfg_down,
    }


def gated_mlp_savings(m: int, d_model: int, d_ff: int,
                      itemsize: int,
                      chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the fused SwiGLU MLP — the number
    benchmarks/bench_fused_epilogue.py asserts (>= 40% at its shape)."""
    unfused = gated_mlp_traffic(m, d_model, d_ff, itemsize, fused=False,
                                chip=chip)
    fused = gated_mlp_traffic(m, d_model, d_ff, itemsize, fused=True,
                              chip=chip)
    saved = 1.0 - fused["total_bytes"] / unfused["total_bytes"]
    return {"unfused_bytes": unfused["total_bytes"],
            "fused_bytes": fused["total_bytes"],
            "saved_frac": saved,
            "unfused": unfused, "fused": fused}


def quant_gemm_traffic(m: int, n: int, k: int, itemsize: int,
                       *, quant: bool,
                       chip: hw.ChipSpec = hw.DEFAULT_CHIP,
                       cfg=None) -> int:
    """HBM bytes for one dense-layer GEMM, full-width vs int8 weights.

    Quantized, the weight stream is 1 byte/element plus a (1, N) f32
    scale row per M-block row (core.blocking.quant_traffic_bytes);
    activations, output and the f32 accumulation are untouched — the
    reduction is pure weight-side bandwidth, which is why it is
    assertable from the static model on a CPU-only container exactly
    like the fused-epilogue wins.
    """
    if cfg is None:
        cfg = blocking.choose_block_config(m, n, k, itemsize, chip=chip)
    if quant:
        return blocking.quant_traffic_bytes(m, n, k, cfg, itemsize)
    return blocking.hbm_traffic_bytes(m, n, k, cfg, itemsize)


def quant_gemm_savings(m: int, n: int, k: int, itemsize: int,
                       chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the int8-weight GEMM — the number
    benchmarks/bench_quant_matmul.py asserts. The same BlockConfig is
    used for both sides (apples-to-apples reuse structure); weight-bound
    shapes (decode: small m, big n*k) approach the full itemsize/1
    reduction, activation-bound shapes see less."""
    cfg = blocking.choose_block_config(m, n, k, itemsize, chip=chip)
    full = quant_gemm_traffic(m, n, k, itemsize, quant=False, chip=chip,
                              cfg=cfg)
    quant = quant_gemm_traffic(m, n, k, itemsize, quant=True, chip=chip,
                               cfg=cfg)
    return {"full_bytes": full,
            "quant_bytes": quant,
            "saved_frac": 1.0 - quant / full,
            "weight_bytes_full": k * n * itemsize,
            "weight_bytes_quant": k * n * 1,
            "cfg": cfg}


def dense_q_layer_savings(m: int, d_model: int, d_ff: int, itemsize: int,
                          chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Whole-MLP view of the int8 win, against the model's REAL
    before state: unquantized SwiGLU runs the fused dual-GEMM kernel
    (one A stream feeds both weights — blocking.gated_traffic_bytes),
    while the quantized path decomposes into two dense_q GEMMs
    (models.layers.gated_apply has no int8 dual-GEMM variant, so the A
    stream is paid twice) + the int8 down-projection. The weight-side
    shrink usually still wins, but decomposition claws some back —
    this is the honest before-to-after delta for Policy(quant="int8")."""
    cfg_hidden = blocking.choose_block_config(m, d_ff, d_model, itemsize,
                                              chip=chip, n_rhs=2)
    full = (blocking.gated_traffic_bytes(m, d_ff, d_model, cfg_hidden,
                                         itemsize)
            + quant_gemm_traffic(m, d_model, d_ff, itemsize, quant=False,
                                 chip=chip))
    quant = (2 * quant_gemm_traffic(m, d_ff, d_model, itemsize, quant=True,
                                    chip=chip)
             + quant_gemm_traffic(m, d_model, d_ff, itemsize, quant=True,
                                  chip=chip))
    return {"full_bytes": full, "quant_bytes": quant,
            "saved_frac": 1.0 - quant / full}


def attention_fwd_savings(tq: int, tk: int, d: int, itemsize: int,
                          cfg: blocking.FlashBlockConfig | None = None,
                          chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the fused flash forward over the
    materialised-softmax baseline, per (batch x head) slice. The win is
    the missing 4*tq*tk*4 S/P round trips, bought back by re-streaming
    K/V once per Q block row — net positive whenever tq*tk dwarfs the
    linear operand terms, i.e. every training shape."""
    if cfg is None:
        cfg = blocking.choose_flash_config(tq, tk, d, itemsize, chip=chip)
    fused = blocking.flash_traffic_bytes(tq, tk, d, cfg, itemsize)
    unfused = blocking.flash_unfused_traffic_bytes(tq, tk, d, itemsize)
    return {"fused_bytes": fused, "unfused_bytes": unfused,
            "saved_frac": 1.0 - fused / unfused, "cfg": cfg}


def decode_attention_savings(pos: int, tk: int, d: int, itemsize: int,
                             cfg: blocking.FlashBlockConfig | None = None,
                             chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the decode kernel over the masked
    dense scan, per (batch x head) — the number
    benchmarks/bench_flash_attention.py asserts. Two independent terms:
    the prefix skip (only ceil((pos+1)/bk)*bk of tk cache rows stream,
    the dominant win early in a long-max-length cache) and the skipped
    (1, tk) f32 score-row round trips."""
    if cfg is None:
        cfg = blocking.choose_decode_config(tk, d, itemsize, chip=chip)
    fused = blocking.decode_traffic_bytes(pos, tk, d, cfg, itemsize)
    unfused = blocking.decode_unfused_traffic_bytes(pos, tk, d, itemsize)
    return {"fused_bytes": fused, "unfused_bytes": unfused,
            "saved_frac": 1.0 - fused / unfused, "cfg": cfg}


def attention_bwd_savings(tq: int, tk: int, d: int, itemsize: int,
                          cfg: blocking.FlashBlockConfig | None = None,
                          chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the recompute-style flash backward
    over the stored-S formulation, per (batch x head). Recompute trades
    the four quadratic f32 trips (P read twice, dS written + re-read)
    for linear re-streams of the operands across both sweeps — the
    classic flash-attention bandwidth argument, backward edition."""
    if cfg is None:
        cfg = blocking.choose_flash_config(tq, tk, d, itemsize, chip=chip)
    fused = blocking.flash_bwd_traffic_bytes(tq, tk, d, cfg, itemsize)
    unfused = blocking.flash_bwd_stored_traffic_bytes(tq, tk, d, itemsize)
    return {"fused_bytes": fused, "unfused_bytes": unfused,
            "saved_frac": 1.0 - fused / unfused, "cfg": cfg}


def ssd_savings(l: int, h: int, p: int, n: int, chunk: int,
                itemsize: int = 4,
                cfg: blocking.SSDBlockConfig | None = None,
                chip: hw.ChipSpec = hw.DEFAULT_CHIP) -> dict:
    """Fractional HBM-byte saving of the fused SSD intra-chunk kernel
    over the XLA chunked lowering — the number
    benchmarks/bench_ssd.py asserts. The unfused composition
    materialises, per chunk and head, the (Q, Q) decay mask and CB
    score block in f32 (write + re-read apiece, the flash-attention
    story with Q = chunk); the fused kernel keeps both VMEM-resident,
    paying only the operand streams and the per-chunk state/diag
    outputs that feed the inter-chunk scan."""
    if cfg is None:
        cfg = blocking.choose_ssd_config(chunk, p, n, itemsize, chip=chip)
    fused = blocking.ssd_traffic_bytes(l, h, p, n, cfg, itemsize)
    unfused = blocking.ssd_unfused_traffic_bytes(l, h, p, n, chunk, itemsize)
    return {"fused_bytes": fused, "unfused_bytes": unfused,
            "saved_frac": 1.0 - fused / unfused, "cfg": cfg}


# ----------------------------------------------------------------------
# KV-cache traffic + capacity models (paged / quantized serving)
# ----------------------------------------------------------------------

def kv_decode_traffic_bytes(pos: int, heads: int, d: int, itemsize: int,
                            *, quant_kv: str = "off") -> int:
    """HBM bytes ONE decode step streams from the KV cache for one slot
    at depth `pos`, summed over K and V: (pos + 1) resident rows per
    side, each `heads * d` elements. quant_kv="int8" rows are 1
    byte/element plus a 4-byte f32 scale per (position, head) — the
    scale planes ride along with the pages, so they are charged here."""
    rows = 2 * (pos + 1) * heads
    if quant_kv == "int8":
        return rows * (d + 4)
    return rows * d * itemsize


def ssm_decode_state_bytes(heads: int, p: int, n: int) -> int:
    """HBM bytes ONE decode step streams for one slot's SSD recurrent
    state: the (H, P, N) f32 state is read and written back once,
    independent of position — the O(1)-state contrast to
    kv_decode_traffic_bytes' O(pos) growth that the serving benchmark's
    long_context rows assert."""
    return 2 * heads * p * n * 4


def kv_quant_savings(pos: int, heads: int, d: int, itemsize: int) -> dict:
    """Fractional KV-byte saving per decode step of int8 pages over
    full-width rows — the number benchmarks/bench_serving.py asserts
    (>= 40%). Decode attention is KV-bandwidth-bound (q is one row, the
    cache is thousands), so byte savings here are latency savings to
    first order: d=64 bf16 rows shrink 128 -> 68 bytes/(row, head)
    (46.9%), f32 rows 256 -> 68 (73.4%)."""
    full = kv_decode_traffic_bytes(pos, heads, d, itemsize)
    quant = kv_decode_traffic_bytes(pos, heads, d, itemsize,
                                    quant_kv="int8")
    return {"full_bytes": full, "quant_bytes": quant,
            "saved_frac": 1.0 - quant / full,
            "row_bytes_full": d * itemsize, "row_bytes_quant": d + 4}


def kv_capacity_model(pool_bytes: int, *, max_len: int, page_size: int,
                      heads: int, d: int, itemsize: int, prompt_len: int,
                      shared_prefix_len: int, gen: int,
                      quant_kv: str = "off") -> dict:
    """Concurrent-slot capacity of one layer's KV memory under three
    layouts at EQUAL byte budget — the static model behind the paged
    engine's >= 2x admission win on prefix-heavy traces.

    * dense: every slot pins max_len rows whether used or not.
    * paged: slots pin ceil((prompt+gen)/page_size) pages; the
      shared-prefix pages are paid once pool-wide.
    * paged + int8: same page count but each page is ~itemsize/1
      smaller, so the same bytes buy proportionally more pages.
    """
    row_full = 2 * heads * d * itemsize          # K + V, one position
    row = 2 * heads * (d + 4) if quant_kv == "int8" else row_full
    dense_slots = pool_bytes // (max_len * row_full)
    n_pages = pool_bytes // (page_size * row)
    shared_pages = shared_prefix_len // page_size   # full pages only
    per_req = -(-(prompt_len + gen) // page_size) - shared_pages
    paged_slots = max(0, (n_pages - shared_pages) // max(per_req, 1))
    return {"dense_slots": int(dense_slots),
            "paged_slots": int(paged_slots),
            "n_pages": int(n_pages),
            "shared_pages": int(shared_pages),
            "pages_per_request": int(per_req),
            "capacity_ratio": paged_slots / max(dense_slots, 1)}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int
    hlo_flops_per_device: float
    hbm_bytes_per_device: float
    ici_bytes_per_device: float
    collectives: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bound: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * devices)
    mfu_roofline: float          # useful-compute-time / dominant term
    memory_analysis: dict
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def summary_line(self) -> str:
        return (f"{self.arch:16s} {self.shape:12s} {self.mesh:10s} "
                f"tc={self.t_compute*1e3:9.3f}ms tm={self.t_memory*1e3:9.3f}ms "
                f"tcoll={self.t_collective*1e3:9.3f}ms bound={self.bound:10s} "
                f"useful={self.useful_ratio:6.3f} mfu*={self.mfu_roofline:6.3f}")


def build_report(
    cfg, cell, *, kind: str, mesh_name: str, n_devices: int,
    hlo_text: str, memory_analysis=None, chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    note: str = "",
) -> RooflineReport:
    costs = H.analyze(hlo_text, n_devices)
    peak = chip.peak_flops_bf16
    t_c = costs.flops / peak
    t_m = costs.hbm_bytes / chip.hbm_bw
    t_coll = costs.ici_bytes / chip.ici_link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    bound = max(terms, key=terms.get)
    mf = model_flops(cfg, cell, kind=kind)
    useful = mf / max(costs.flops * n_devices, 1.0)
    t_useful = mf / n_devices / peak
    mfu = t_useful / max(max(terms.values()), 1e-30)

    ma = {}
    if memory_analysis is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            ma[k] = getattr(memory_analysis, k, None)

    return RooflineReport(
        arch=cfg.name, shape=cell.name, mesh=mesh_name, kind=kind,
        n_devices=n_devices,
        hlo_flops_per_device=costs.flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        ici_bytes_per_device=costs.ici_bytes,
        collectives=costs.collective_summary(),
        t_compute=t_c, t_memory=t_m, t_collective=t_coll, bound=bound,
        model_flops_total=mf, useful_ratio=useful, mfu_roofline=mfu,
        memory_analysis=ma, note=note,
    )


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
