"""Roofline terms per (arch x shape x mesh) from a compiled dry-run.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = ICI_bytes_per_device / link_bw

HLO_FLOPs / bytes / collective bytes come from roofline.hlo (the
while-loop-aware static analyzer; compiled.cost_analysis() undercounts
scanned stacks — verified, see EXPERIMENTS §Dry-run). MODEL_FLOPS is
the 6·N·D / 2·N·D convention (N = active params for MoE), so the
MODEL_FLOPS/HLO_FLOPs ratio exposes remat and redundant compute.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax

from repro.core import hw
from repro.roofline import hlo as H


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts via eval_shape (no allocation)."""
    from repro.models import model as M

    shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    expert_total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if "moe/w_" in pstr:
            expert_total += n
    active = total
    if cfg.moe is not None and expert_total:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - expert_total + int(expert_total * frac)
    return total, active


def model_flops(cfg, cell, *, kind: str) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (one decode step),
    N = active params (MoE), D = tokens processed. Attention flops
    excluded by convention (noted in EXPERIMENTS)."""
    _, active = count_params(cfg)
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * cell.global_batch         # decode: one token/seq


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int
    hlo_flops_per_device: float
    hbm_bytes_per_device: float
    ici_bytes_per_device: float
    collectives: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bound: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * devices)
    mfu_roofline: float          # useful-compute-time / dominant term
    memory_analysis: dict
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def summary_line(self) -> str:
        return (f"{self.arch:16s} {self.shape:12s} {self.mesh:10s} "
                f"tc={self.t_compute*1e3:9.3f}ms tm={self.t_memory*1e3:9.3f}ms "
                f"tcoll={self.t_collective*1e3:9.3f}ms bound={self.bound:10s} "
                f"useful={self.useful_ratio:6.3f} mfu*={self.mfu_roofline:6.3f}")


def build_report(
    cfg, cell, *, kind: str, mesh_name: str, n_devices: int,
    hlo_text: str, memory_analysis=None, chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    note: str = "",
) -> RooflineReport:
    costs = H.analyze(hlo_text, n_devices)
    peak = chip.peak_flops_bf16
    t_c = costs.flops / peak
    t_m = costs.hbm_bytes / chip.hbm_bw
    t_coll = costs.ici_bytes / chip.ici_link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    bound = max(terms, key=terms.get)
    mf = model_flops(cfg, cell, kind=kind)
    useful = mf / max(costs.flops * n_devices, 1.0)
    t_useful = mf / n_devices / peak
    mfu = t_useful / max(max(terms.values()), 1e-30)

    ma = {}
    if memory_analysis is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            ma[k] = getattr(memory_analysis, k, None)

    return RooflineReport(
        arch=cfg.name, shape=cell.name, mesh=mesh_name, kind=kind,
        n_devices=n_devices,
        hlo_flops_per_device=costs.flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        ici_bytes_per_device=costs.ici_bytes,
        collectives=costs.collective_summary(),
        t_compute=t_c, t_memory=t_m, t_collective=t_coll, bound=bound,
        model_flops_total=mf, useful_ratio=useful, mfu_roofline=mfu,
        memory_analysis=ma, note=note,
    )


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
