"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the allclose sweeps in tests/ and the
'sequential algorithm' stand-ins for the paper's CPU baselines.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """C = A @ B with f32 accumulation (the paper's Cauchy product)."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else (
        jnp.complex64 if jnp.issubdtype(a.dtype, jnp.complexfloating) else jnp.float32)
    return jnp.matmul(a, b, preferred_element_type=acc_dtype).astype(out_dtype)


def dequantize_ref(wq: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the float weight from int8 + per-channel scale."""
    return wq.astype(scale.dtype) * scale


def matmul_q_ref(a: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                 out_dtype=None) -> jnp.ndarray:
    """Dequantized GEMM oracle: ``(A @ Wq) * scale`` with the scale
    applied on the accumulator — per-channel scales are constant along
    k so they commute with the contraction, which is exactly where the
    tiled kernel applies them (the flush phase). Wq is cast to A's
    dtype in place of a dequantize pass: int8 magnitudes (<= 127) are
    exact in bf16 and f32 alike."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    acc = jnp.matmul(a, wq.astype(a.dtype), preferred_element_type=acc_dtype)
    return (acc * scale.reshape(1, -1).astype(acc_dtype)).astype(out_dtype)


def epilogue_ref(y: jnp.ndarray, epilogue: str,
                 bias: jnp.ndarray | None = None,
                 residual: jnp.ndarray | None = None) -> jnp.ndarray:
    """Unfused composition of the kernel epilogues (kernels.matmul
    EPILOGUES) — the XLA path and the parity oracle for the fused flush."""
    if epilogue == "none":
        return y
    if epilogue == "residual":
        return y + residual.astype(y.dtype)
    y = y + bias.reshape(-1).astype(y.dtype)
    if epilogue == "bias_gelu":
        y = jax.nn.gelu(y)
    elif epilogue == "bias_silu":
        y = jax.nn.silu(y)
    return y


def gated_matmul_ref(a: jnp.ndarray, w_gate: jnp.ndarray,
                     w_up: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """silu(A @ Wg) * (A @ Wu) with f32 accumulation, gate product in
    the accumulator dtype — the oracle for the dual-GEMM kernel."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    g = jnp.matmul(a, w_gate, preferred_element_type=acc_dtype)
    u = jnp.matmul(a, w_up, preferred_element_type=acc_dtype)
    return (jax.nn.silu(g) * u).astype(out_dtype)


def add_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


def sub_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a - b


def saxpy_ref(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return alpha * x + y


def attention_ref(
    q: jnp.ndarray,              # [B, Tq, H, D]
    k: jnp.ndarray,              # [B, Tk, Hkv, D]
    v: jnp.ndarray,              # [B, Tk, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,   # sliding-window size (None = full)
    scale: float | None = None,
    q_offset=0,                  # absolute position of q[0] (decode):
                                 # scalar, or (B,) per-row vector
) -> jnp.ndarray:
    """Dense softmax attention oracle with GQA broadcast + masks."""
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # broadcast kv heads across the query-head group
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(tq)[None, :, None] + \
        (q_off[:, None, None] if q_off.ndim else q_off)   # (Bm, Tq, 1)
    k_pos = jnp.arange(tk)[None, None, :]
    mask = jnp.ones((1, tq, tk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[:, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


_LSE_EMPTY = 1e30    # fully-masked-row sentinel; see flash_attention.py


def _attention_logits(q, k, *, causal, window, scale, q_offset):
    """(scaled, masked) logits + mask shared by the fwd/bwd oracles."""
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(tq)[None, :, None] + \
        (q_off[:, None, None] if q_off.ndim else q_off)
    k_pos = jnp.arange(tk)[None, None, :]
    mask = jnp.ones((1, tq, tk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return jnp.where(mask[:, None], logits, -1e30), mask, g, scale


def attention_fwd_ref(
    q: jnp.ndarray,              # [B, Tq, H, D]
    k: jnp.ndarray,              # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset=0,
):
    """attention_ref plus the (B, H, Tq) f32 logsumexp residual — the
    XLA twin of flash_attention(..., return_lse=True). Fully-masked
    rows get the +1e30 sentinel so the backward's P = exp(S - lse)
    vanishes for them."""
    logits, mask, g, _ = _attention_logits(
        q, k, causal=causal, window=window, scale=scale, q_offset=q_offset)
    any_valid = jnp.any(jnp.broadcast_to(mask[:, None], logits.shape),
                        axis=-1)
    lse = jnp.where(any_valid,
                    jax.scipy.special.logsumexp(logits, axis=-1),
                    _LSE_EMPTY)                            # (B, H, Tq)
    p = jnp.exp(logits - lse[..., None])
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype), lse


def paged_gather_ref(
    kp: jnp.ndarray,             # [P, page_size, Hkv, D] page pool
    table: jnp.ndarray,          # [B, pages_per_slot] int32; -1 unmapped
    scales: jnp.ndarray | None = None,   # [P, Hkv, page_size] f32
) -> jnp.ndarray:
    """Materialise each slot's logical K/V tensor from the page pool:
    logical page j of slot b is pool page table[b, j], covering key
    positions [j*page_size, (j+1)*page_size). Unmapped entries clamp to
    page 0 — the caller's causal mask (pos < j*page_size) hides them.
    int8 pools dequantize against the per-(position, head) scales.
    Returns [B, pages_per_slot*page_size, Hkv, D]."""
    b, pp = table.shape
    n_pages, ps, hkv, d = kp.shape
    idx = jnp.maximum(jnp.asarray(table, jnp.int32), 0)
    gathered = kp[idx]                          # (B, pp, ps, Hkv, D)
    if scales is not None:
        s = scales[idx]                         # (B, pp, Hkv, ps)
        gathered = gathered.astype(jnp.float32) \
            * s.transpose(0, 1, 3, 2)[..., None]
    return gathered.reshape(b, pp * ps, hkv, d)


def flash_decode_paged_ref(
    q: jnp.ndarray,              # [B, 1, H, D]
    kp: jnp.ndarray,             # [P, page_size, Hkv, D]
    vp: jnp.ndarray,
    table: jnp.ndarray,          # [B, pages_per_slot] int32
    *,
    pos=0,                       # scalar or (B,) per-slot depth
    window: int | None = None,
    scale: float | None = None,
    ks: jnp.ndarray | None = None,
    vs: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Dense oracle for the paged decode kernel: gather + dequantize
    the pool through the page table, then the masked attention_fwd_ref
    at q_offset=pos. Returns [B, 1, H, D]."""
    k = paged_gather_ref(kp, table, ks)
    v = paged_gather_ref(vp, table, vs)
    out, _ = attention_fwd_ref(q, k, v, causal=True, window=window,
                               scale=scale, q_offset=pos)
    return out.astype(q.dtype)


def ssd_ref(
    x: jnp.ndarray,              # (B, L, H, P) — dt-scaled
    a: jnp.ndarray,              # (B, L, H)    — dt * A (log-decay)
    b_: jnp.ndarray,             # (B, L, G, N)
    c_: jnp.ndarray,             # (B, L, G, N)
    chunk: int,                  # unused: the scan is chunk-free
    init_state: jnp.ndarray | None = None,   # (B, H, P, N)
):
    """Sequential per-token SSD oracle (the 'naive' backend): the plain
    rank-N linear recurrence s_t = s_{t-1}·exp(a_t) + x_t b_tᵀ,
    y_t = s_t c_t, in f32 with no chunking at all — ground truth for
    every chunked formulation (chunking is algebraically exact, so
    `chunk` is accepted for signature parity and ignored). Returns
    (y in x.dtype, final_state f32 (B, H, P, N))."""
    bsz, l, h, p = x.shape
    g, n = b_.shape[-2:]
    rep = h // g
    acc = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
    xf = x.astype(acc)
    af = a.astype(acc)
    bf = jnp.repeat(b_.astype(acc), rep, axis=2)           # (B,L,H,N)
    cf = jnp.repeat(c_.astype(acc), rep, axis=2)
    s0 = (jnp.zeros((bsz, h, p, n), acc)
          if init_state is None else init_state.astype(acc))

    def step(s, inp):
        x_t, a_t, b_t, c_t = inp                           # (B,H,P)/(B,H)/...
        s = s * jnp.exp(a_t)[..., None, None] \
            + jnp.einsum("bhp,bhn->bhpn", x_t, b_t)
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, s)
        return s, y_t

    s_final, ys = jax.lax.scan(
        step, s0,
        (xf.swapaxes(0, 1), af.swapaxes(0, 1),
         bf.swapaxes(0, 1), cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), s_final


def attention_bwd_ref(
    q: jnp.ndarray,              # [B, Tq, H, D]
    k: jnp.ndarray,              # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    o: jnp.ndarray,              # [B, Tq, H, D]  forward output
    do: jnp.ndarray,             # [B, Tq, H, D]  output cotangent
    lse: jnp.ndarray,            # [B, H, Tq] f32 forward residual
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset=0,
):
    """Closed-form attention backward from the saved (o, lse) residuals
    — the dense oracle for the recompute-style Pallas kernel, GQA
    group-sum included. Returns (dq, dk, dv) in the input dtypes."""
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    logits, _, g, scale = _attention_logits(
        q, k, causal=causal, window=window, scale=scale, q_offset=q_offset)
    p = jnp.exp(logits - lse.astype(jnp.float32)[..., None])  # (B,H,Tq,Tk)
    dof = do.astype(jnp.float32)
    of = o.astype(jnp.float32)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    qs = q.astype(jnp.float32) * scale

    dv = jnp.einsum("bhqk,bqhd->bkhd", p, dof)             # per q-head
    dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vf)
    delta = jnp.sum(dof * of, axis=-1)                     # (B, Tq, H)
    ds = p * (dp - delta.transpose(0, 2, 1)[..., None])
    dq = scale * jnp.einsum("bhqk,bkhd->bqhd", ds, kf)
    dk = jnp.einsum("bhqk,bqhd->bkhd", ds, qs)             # per q-head
    # GQA: each kv head accumulates its group of query heads
    dk = dk.reshape(b, tk, hkv, g, d).sum(axis=3)
    dv = dv.reshape(b, tk, hkv, g, d).sum(axis=3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
