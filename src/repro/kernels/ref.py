"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the allclose sweeps in tests/ and the
'sequential algorithm' stand-ins for the paper's CPU baselines.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """C = A @ B with f32 accumulation (the paper's Cauchy product)."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else (
        jnp.complex64 if jnp.issubdtype(a.dtype, jnp.complexfloating) else jnp.float32)
    return jnp.matmul(a, b, preferred_element_type=acc_dtype).astype(out_dtype)


def dequantize_ref(wq: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the float weight from int8 + per-channel scale."""
    return wq.astype(scale.dtype) * scale


def matmul_q_ref(a: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                 out_dtype=None) -> jnp.ndarray:
    """Dequantized GEMM oracle: ``(A @ Wq) * scale`` with the scale
    applied on the accumulator — per-channel scales are constant along
    k so they commute with the contraction, which is exactly where the
    tiled kernel applies them (the flush phase). Wq is cast to A's
    dtype in place of a dequantize pass: int8 magnitudes (<= 127) are
    exact in bf16 and f32 alike."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    acc = jnp.matmul(a, wq.astype(a.dtype), preferred_element_type=acc_dtype)
    return (acc * scale.reshape(1, -1).astype(acc_dtype)).astype(out_dtype)


def epilogue_ref(y: jnp.ndarray, epilogue: str,
                 bias: jnp.ndarray | None = None,
                 residual: jnp.ndarray | None = None) -> jnp.ndarray:
    """Unfused composition of the kernel epilogues (kernels.matmul
    EPILOGUES) — the XLA path and the parity oracle for the fused flush."""
    if epilogue == "none":
        return y
    if epilogue == "residual":
        return y + residual.astype(y.dtype)
    y = y + bias.reshape(-1).astype(y.dtype)
    if epilogue == "bias_gelu":
        y = jax.nn.gelu(y)
    elif epilogue == "bias_silu":
        y = jax.nn.silu(y)
    return y


def gated_matmul_ref(a: jnp.ndarray, w_gate: jnp.ndarray,
                     w_up: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """silu(A @ Wg) * (A @ Wu) with f32 accumulation, gate product in
    the accumulator dtype — the oracle for the dual-GEMM kernel."""
    if out_dtype is None:
        out_dtype = a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    g = jnp.matmul(a, w_gate, preferred_element_type=acc_dtype)
    u = jnp.matmul(a, w_up, preferred_element_type=acc_dtype)
    return (jax.nn.silu(g) * u).astype(out_dtype)


def add_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


def sub_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a - b


def saxpy_ref(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return alpha * x + y


def attention_ref(
    q: jnp.ndarray,              # [B, Tq, H, D]
    k: jnp.ndarray,              # [B, Tk, Hkv, D]
    v: jnp.ndarray,              # [B, Tk, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,   # sliding-window size (None = full)
    scale: float | None = None,
    q_offset=0,                  # absolute position of q[0] (decode):
                                 # scalar, or (B,) per-row vector
) -> jnp.ndarray:
    """Dense softmax attention oracle with GQA broadcast + masks."""
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # broadcast kv heads across the query-head group
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(tq)[None, :, None] + \
        (q_off[:, None, None] if q_off.ndim else q_off)   # (Bm, Tq, 1)
    k_pos = jnp.arange(tk)[None, None, :]
    mask = jnp.ones((1, tq, tk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[:, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
