"""Kernel registry: op-name × backend-name → implementation.

Implementations self-register at import time:

    @register_op("matmul", backend="pallas")
    def _matmul_pallas(a, b, *, policy, ...): ...

and the public dispatchers in kernels.ops become thin validated
lookups instead of if/elif chains over backend strings. The registry is
ALSO the single source of truth for "what exists": unknown op or
backend names raise ValueError messages that list exactly the
registered options, so adding a backend (a new @register_op call) is
the whole change — no hand-maintained MATMUL_BACKENDS tuple, no N call
sites to edit. (AttentionEngine's declarative op/template table is the
model here; see ISSUE/PAPERS.md.)

This module is a leaf on purpose — no jax, no repro imports — so both
core.policy and kernels.ops can depend on it without cycles.
"""

from __future__ import annotations

from typing import Callable, Dict

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register_op(op: str, *, backend: str) -> Callable[[Callable], Callable]:
    """Decorator: register `fn` as the implementation of `op` on
    `backend`. Re-registration replaces (tests swap spies in)."""
    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn
    return deco


def get_impl(op: str, backend: str) -> Callable:
    impls = _REGISTRY.get(op)
    if impls is None:
        raise ValueError(
            f"unknown op {op!r}; registered ops: {registered_ops()}")
    impl = impls.get(backend)
    if impl is None:
        raise ValueError(
            f"op {op!r} has no backend {backend!r}; registered backends: "
            f"{registered_backends(op)} (legacy spellings like "
            "'tuned_interpret' map through Policy.from_backend)")
    return impl


def registered_ops() -> tuple:
    return tuple(sorted(_REGISTRY))


def registered_backends(op: str) -> tuple:
    if op not in _REGISTRY:
        raise ValueError(
            f"unknown op {op!r}; registered ops: {registered_ops()}")
    return tuple(sorted(_REGISTRY[op]))
