"""Policy-dispatched wrappers around the Pallas kernels.

Handles the plumbing the kernels themselves keep out of scope: shape
padding to block multiples, block-size choice via core.blocking (the
paper's shared-memory sizing argument), epilogue operand validation,
and the interpret-mode fallback used on CPU-only containers.

Execution selection is typed: every public op takes a
`core.policy.Policy` (explicit `policy=`, or the ambient
`policy.scope()` default) and dispatches through the kernel registry
(kernels.registry):

    op name     registered backends
    matmul      xla (jnp reference) | pallas (tiled, Listing 4) |
                naive (hierarchy-blind, Listing 3)
    matmul_q    xla (dequantized reference) | pallas (int8-weight
                tiled kernel, flush-phase dequant) | naive (dequantize
                then hierarchy-blind)
    gated_matmul  xla/naive (unfused compose) | pallas (dual-GEMM)
    flash_attention  xla (reference) | pallas (flash kernel)
    flash_attention_bwd  xla (closed-form ref) | pallas (recompute-
                style two-sweep kernel, S/P never in HBM)
    flash_decode  xla (ref composition) | pallas (q_len=1 kernel,
                prefix-only K/V streaming)
    flash_decode_paged  xla (page-gather + ref composition) | pallas
                (scalar-prefetched page-table gather, optional int8
                in-kernel dequant)
    ssd         xla (chunked jnp composition) | naive (sequential
                per-token scan oracle) | pallas (intra-chunk Pallas
                kernel: decay mask + CB scores VMEM-resident)
    add / sub   xla | pallas/naive (elementwise kernel)

`policy.interpret` (None = auto off-TPU) decides interpreter vs.
compiled for every Pallas op — no per-op suffix sniffing.
`policy.autotune == "cached"` serves tile winners from the autotuner
cache (repro.tuning) with the static core.blocking chooser as fallback;
the legacy "tuned"/"tuned_interpret" backend strings map onto exactly
that policy via the compat shims at the bottom of this module (the only
place backend strings are still interpreted).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blocking, hw
from repro.core import policy as _policy
from repro.core.policy import Policy
from repro.kernels import elementwise as _ew
from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import matmul_naive as _mmn
from repro.kernels import ref as _ref
from repro.kernels import registry as _registry
from repro.kernels import ssd as _ssd
from repro.kernels.registry import register_op
from repro.tuning import cache as _tcache


# ----------------------------------------------------------------------
# shared helpers
# ----------------------------------------------------------------------

def _pad2(x: jnp.ndarray, m_to: int, n_to: int) -> jnp.ndarray:
    m, n = x.shape
    if m == m_to and n == n_to:
        return x
    return jnp.pad(x, ((0, m_to - m), (0, n_to - n)))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _usable_block(block, served: bool) -> bool:
    """Discard degenerate tile configs (a corrupt cache entry must fall
    back to the static chooser, not crash the padding arithmetic)."""
    if block is None:
        return False
    ok = block.bm > 0 and block.bn > 0 and block.bk > 0
    if not ok and not served:
        raise ValueError(f"invalid block config {block}")
    return ok


def _check_epilogue(epilogue: str) -> None:
    """Validated against the kernel's own lattice (kernels.matmul
    EPILOGUES) — the registry of fused flushes, not a local tuple."""
    if epilogue not in _mm.EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; registered "
                         f"epilogues: {_mm.EPILOGUES}")


def _epilogue_operand(epilogue, bias, residual, m, n, mp, np_):
    """Validate + pad the flush-phase operand to the padded tile grid.
    The operand keeps its own dtype — the kernel casts it to the
    accumulator dtype, mirroring the unfused ref.epilogue_ref cast, so
    a residual/bias wider than the inputs loses no precision."""
    if epilogue == "none":
        if bias is not None or residual is not None:
            raise ValueError("bias/residual operands need an epilogue")
        return None
    if epilogue == "residual":
        if residual is None or residual.shape != (m, n):
            raise ValueError(
                f"epilogue='residual' needs residual of shape {(m, n)}, "
                f"got {None if residual is None else residual.shape}")
        return _pad2(residual, mp, np_)
    if bias is None:
        raise ValueError(f"epilogue={epilogue!r} needs bias=")
    e = bias.reshape(1, -1)
    if e.shape != (1, n):
        raise ValueError(f"bias shape {bias.shape} incompatible with n={n}")
    return _pad2(e, 1, np_)


# ----------------------------------------------------------------------
# matmul implementations (self-registered)
# ----------------------------------------------------------------------

@register_op("matmul", backend="xla")
def _matmul_xla(a, b, *, policy, out_dtype, block, epilogue, bias, residual):
    y = _ref.matmul_ref(a, b, out_dtype=out_dtype)
    return _ref.epilogue_ref(y, epilogue, bias, residual)


@register_op("matmul", backend="naive")
def _matmul_naive(a, b, *, policy, out_dtype, block, epilogue, bias,
                  residual):
    m, k = a.shape
    n = b.shape[1]
    chip = policy.chip
    itemsize = jnp.dtype(a.dtype).itemsize
    sub = chip.sublane(itemsize)
    mp, np_ = _round_up(m, sub), _round_up(n, chip.lane)
    out = _mmn.matmul_naive(
        _pad2(a, mp, k), _pad2(b, k, np_),
        out_dtype=out_dtype, interpret=policy.resolved_interpret)[:m, :n]
    return _ref.epilogue_ref(out, epilogue, bias, residual)


@register_op("matmul", backend="pallas")
def _matmul_pallas(a, b, *, policy, out_dtype, block, epilogue, bias,
                   residual):
    m, k = a.shape
    n = b.shape[1]
    served = False
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_matmul(
            m, n, k, a.dtype, policy, epilogue=epilogue)
        served = block is not None
        # miss / fingerprint mismatch -> block stays None and the
        # static chooser below picks the paper's default tiles.
    itemsize = jnp.dtype(a.dtype).itemsize
    if not _usable_block(block, served):
        block = blocking.choose_block_config(m, n, k, itemsize, policy.chip)
    # padding to block multiples guarantees the kernel's clamp
    # re-validation passes: every dim is a multiple of its tile edge.
    mp = _round_up(m, block.bm)
    np_ = _round_up(n, block.bn)
    kp = _round_up(k, block.bk)
    e = _epilogue_operand(epilogue, bias, residual, m, n, mp, np_)
    out = _mm.matmul_tiled(
        _pad2(a, mp, kp), _pad2(b, kp, np_),
        bm=block.bm, bn=block.bn, bk=block.bk,
        out_dtype=out_dtype, interpret=policy.resolved_interpret,
        epilogue=epilogue, epilogue_operand=e)
    return out[:m, :n]


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    policy: Policy | None = None,
    backend: str | None = None,        # deprecated string shim
    out_dtype=None,
    block: blocking.BlockConfig | None = None,
    chip: hw.ChipSpec | None = None,
    epilogue: str = "none",
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """2D real GEMM through the policy-selected backend, padding as
    needed.

    epilogue/bias/residual select a fused flush (kernels.matmul
    EPILOGUES): the pallas backend applies it inside the kernel on the
    f32 accumulator; xla and naive apply the same composition unfused
    (ref.epilogue_ref), so every backend computes the same function.
    """
    assert a.ndim == 2 and b.ndim == 2, (a.shape, b.shape)
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    pol = _policy.resolve(policy, backend)
    if chip is not None and chip is not pol.chip:
        pol = pol.replace(chip=chip)
    _check_epilogue(epilogue)
    out_dtype = out_dtype or pol.resolved_out_dtype(a.dtype)
    impl = _registry.get_impl("matmul", pol.backend)
    return impl(a, b, policy=pol, out_dtype=out_dtype, block=block,
                epilogue=epilogue, bias=bias, residual=residual)


# ----------------------------------------------------------------------
# quantized matmul (int8 weights, per-channel scales)
# ----------------------------------------------------------------------

def _check_quant_operands(wq, scale, k, n):
    """Validate the (Wq, scale) pair and normalise scale to (1, n) —
    the kernel's BlockSpec layout."""
    if wq.dtype != jnp.int8:
        raise ValueError(f"matmul_q weights must be int8 "
                         f"(core.precision.quantize_int8), got {wq.dtype}")
    if wq.shape != (k, n):
        raise ValueError(f"quantized weight shape {wq.shape} incompatible "
                         f"with ({k}, {n})")
    s = scale.reshape(1, -1) if scale.ndim == 1 else scale
    if s.shape != (1, n):
        raise ValueError(f"per-channel scale shape {scale.shape} "
                         f"incompatible with n={n}; expected ({n},) or "
                         f"(1, {n})")
    if not jnp.issubdtype(s.dtype, jnp.floating):
        raise ValueError(f"scale must be floating, got {s.dtype}")
    return s


@register_op("matmul_q", backend="xla")
def _matmul_q_xla(a, wq, scale, *, policy, out_dtype, block, epilogue,
                  bias, residual):
    y = _ref.matmul_q_ref(a, wq, scale, out_dtype=out_dtype)
    return _ref.epilogue_ref(y, epilogue, bias, residual)


@register_op("matmul_q", backend="naive")
def _matmul_q_naive(a, wq, scale, *, policy, out_dtype, block, epilogue,
                    bias, residual):
    """Dequantize in HBM, then the hierarchy-blind kernel — the
    fallback composition (no traffic win, same function)."""
    w = _ref.dequantize_ref(wq, scale).astype(a.dtype)
    return _matmul_naive(a, w, policy=policy, out_dtype=out_dtype,
                         block=block, epilogue=epilogue, bias=bias,
                         residual=residual)


@register_op("matmul_q", backend="pallas")
def _matmul_q_pallas(a, wq, scale, *, policy, out_dtype, block, epilogue,
                     bias, residual):
    m, k = a.shape
    n = wq.shape[1]
    served = False
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_matmul_q(
            m, n, k, a.dtype, policy, epilogue=epilogue)
        served = block is not None
    itemsize = jnp.dtype(a.dtype).itemsize
    if not _usable_block(block, served):
        # tiles sized by the activation itemsize: conservative for the
        # 1-byte W stream (a dedicated int8 chooser could go larger).
        block = blocking.choose_block_config(m, n, k, itemsize, policy.chip)
    mp = _round_up(m, block.bm)
    np_ = _round_up(n, block.bn)
    kp = _round_up(k, block.bk)
    e = _epilogue_operand(epilogue, bias, residual, m, n, mp, np_)
    out = _mm.matmul_q_tiled(
        _pad2(a, mp, kp), _pad2(wq, kp, np_), _pad2(scale, 1, np_),
        bm=block.bm, bn=block.bn, bk=block.bk,
        out_dtype=out_dtype, interpret=policy.resolved_interpret,
        epilogue=epilogue, epilogue_operand=e)
    return out[:m, :n]


def matmul_q(
    a: jnp.ndarray,
    wq: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    policy: Policy | None = None,
    backend: str | None = None,        # deprecated string shim
    out_dtype=None,
    block: blocking.BlockConfig | None = None,
    chip: hw.ChipSpec | None = None,
    epilogue: str = "none",
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """2D GEMM against int8 per-channel-quantized weights:
    ``epilogue((a @ wq) * scale)``.

    The pallas backend streams the weight tiles as int8 and dequantizes
    on the f32 accumulator in the flush (kernels.matmul.matmul_q_tiled);
    xla/naive compute the same function from the dequantized composition
    — every backend is conformance-tested against the ref oracle in
    tests/test_property.py. Quantize weights once with
    core.precision.quantize_int8; training-time cotangents live in
    core.gemm.dense_q.
    """
    assert a.ndim == 2 and wq.ndim == 2, (a.shape, wq.shape)
    assert a.shape[1] == wq.shape[0], (a.shape, wq.shape)
    pol = _policy.resolve(policy, backend)
    if chip is not None and chip is not pol.chip:
        pol = pol.replace(chip=chip)
    _check_epilogue(epilogue)
    scale = _check_quant_operands(wq, scale, a.shape[1], wq.shape[1])
    out_dtype = out_dtype or pol.resolved_out_dtype(a.dtype)
    impl = _registry.get_impl("matmul_q", pol.backend)
    return impl(a, wq, scale, policy=pol, out_dtype=out_dtype, block=block,
                epilogue=epilogue, bias=bias, residual=residual)


# ----------------------------------------------------------------------
# gated matmul (SwiGLU dual-GEMM)
# ----------------------------------------------------------------------

@register_op("gated_matmul", backend="xla")
@register_op("gated_matmul", backend="naive")
def _gated_compose(a, w_gate, w_up, *, policy, out_dtype, block):
    """Unfused composition through the plain matmul dispatcher: the
    xla/naive backends compute the same function with two GEMMs and an
    HBM intermediate."""
    g = matmul(a, w_gate, policy=policy, out_dtype=out_dtype)
    u = matmul(a, w_up, policy=policy, out_dtype=out_dtype)
    return (jax.nn.silu(g) * u).astype(out_dtype)


@register_op("gated_matmul", backend="pallas")
def _gated_pallas(a, w_gate, w_up, *, policy, out_dtype, block):
    m, k = a.shape
    n = w_gate.shape[1]
    served = False
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_gated(m, n, k, a.dtype, policy)
        served = block is not None
    itemsize = jnp.dtype(a.dtype).itemsize
    if not _usable_block(block, served):
        block = blocking.choose_block_config(m, n, k, itemsize, policy.chip,
                                             n_rhs=2)
    mp = _round_up(m, block.bm)
    np_ = _round_up(n, block.bn)
    kp = _round_up(k, block.bk)
    out = _mm.gated_matmul_tiled(
        _pad2(a, mp, kp), _pad2(w_gate, kp, np_), _pad2(w_up, kp, np_),
        bm=block.bm, bn=block.bn, bk=block.bk,
        out_dtype=out_dtype, interpret=policy.resolved_interpret)
    return out[:m, :n]


def gated_matmul(
    a: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    *,
    policy: Policy | None = None,
    backend: str | None = None,        # deprecated string shim
    out_dtype=None,
    block: blocking.BlockConfig | None = None,
    chip: hw.ChipSpec | None = None,
) -> jnp.ndarray:
    """silu(a @ w_gate) * (a @ w_up) — the SwiGLU hidden phase.

    The pallas backend runs the dual-GEMM kernel (one A stream, two
    weight operands, zero HBM intermediates); xla/naive compose it
    unfused. Tiles come from the gated autotuner cache entries
    (policy.autotune == "cached") or the n_rhs=2 static chooser."""
    assert a.ndim == w_gate.ndim == w_up.ndim == 2
    assert w_gate.shape == w_up.shape == (a.shape[1], w_gate.shape[1])
    pol = _policy.resolve(policy, backend)
    if chip is not None and chip is not pol.chip:
        pol = pol.replace(chip=chip)
    out_dtype = out_dtype or pol.resolved_out_dtype(a.dtype)
    impl = _registry.get_impl("gated_matmul", pol.backend)
    return impl(a, w_gate, w_up, policy=pol, out_dtype=out_dtype,
                block=block)


# ----------------------------------------------------------------------
# elementwise
# ----------------------------------------------------------------------

@register_op("add", backend="xla")
def _add_xla(a, b, *, policy):
    return _ref.add_ref(a, b)


@register_op("add", backend="pallas")
@register_op("add", backend="naive")
def _add_pallas(a, b, *, policy):
    return _ew.binary_op(a, b, "add", interpret=policy.resolved_interpret)


@register_op("sub", backend="xla")
def _sub_xla(a, b, *, policy):
    return _ref.sub_ref(a, b)


@register_op("sub", backend="pallas")
@register_op("sub", backend="naive")
def _sub_pallas(a, b, *, policy):
    return _ew.binary_op(a, b, "sub", interpret=policy.resolved_interpret)


def _elementwise(op, a, b, policy, backend, interpret):
    pol = _policy.resolve(policy, backend)
    if interpret is not None:
        # explicit bool overrides the policy (e.g. force-interpret on
        # CPU regardless of what the ambient policy says).
        pol = pol.replace(interpret=interpret)
    return _registry.get_impl(op, pol.backend)(a, b, policy=pol)


def add(a, b, *, policy: Policy | None = None, backend: str | None = None,
        interpret: bool | None = None):
    return _elementwise("add", a, b, policy, backend, interpret)


def sub(a, b, *, policy: Policy | None = None, backend: str | None = None,
        interpret: bool | None = None):
    return _elementwise("sub", a, b, policy, backend, interpret)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@register_op("flash_attention", backend="xla")
def _flash_xla(q, k, v, *, policy, causal, window, q_offset, bq, bk, block):
    return _ref.attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset)


@register_op("flash_attention", backend="pallas")
def _flash_pallas(q, k, v, *, policy, causal, window, q_offset, bq, bk,
                  block):
    b_, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    if jnp.asarray(q_offset).ndim == 1:
        # per-batch offsets -> per-(batch*head) rows of the flat layout
        q_offset = jnp.repeat(jnp.asarray(q_offset, jnp.int32), h)
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_flash(tq, tk, d, q.dtype, policy)
    if block is not None:
        bq, bk = block.bq, block.bk
    g = h // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b_ * h, tq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b_ * hkv, tk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b_ * hkv, tk, d)
    o = _fa.flash_attention(
        qf, kf, vf, group=g, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk,
        interpret=policy.resolved_interpret)
    return o.reshape(b_, h, tq, d).transpose(0, 2, 1, 3)


def flash_attention(
    q: jnp.ndarray,            # [B, Tq, H, D]
    k: jnp.ndarray,            # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,                # scalar, or (B,) per-row vector (decode)
    policy: Policy | None = None,
    backend: str | None = None,        # deprecated string shim
    bq: int = 256,
    bk: int = 512,
    block: blocking.FlashBlockConfig | None = None,
) -> jnp.ndarray:
    """Layout-normalising wrapper: model code uses [B, T, H, D]."""
    pol = _policy.resolve(policy, backend)
    impl = _registry.get_impl("flash_attention", pol.backend)
    return impl(q, k, v, policy=pol, causal=causal, window=window,
                q_offset=q_offset, bq=bq, bk=bk, block=block)


def _flat_heads(x):
    """[B, T, H, D] -> the kernels' flat [B*H, T, D] layout."""
    b, t, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, d)


def _per_head(offset, h):
    """Broadcast a (B,) per-batch offset vector to the flat layout's
    per-(batch*head) rows; scalars pass through."""
    if jnp.asarray(offset).ndim == 1:
        return jnp.repeat(jnp.asarray(offset, jnp.int32), h)
    return offset


def flash_attention_fwd(
    q: jnp.ndarray,            # [B, Tq, H, D]
    k: jnp.ndarray,            # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,
    policy: Policy | None = None,
    backend: str | None = None,
    bq: int = 256,
    bk: int = 512,
    block: blocking.FlashBlockConfig | None = None,
):
    """Forward with residuals: (o, lse[B, H, Tq] f32) — what the
    attention custom-VJP saves for flash_attention_bwd. Not a separate
    registry op: it IS flash_attention plus the lse output, so it
    follows the same backend split (pallas = kernel, else = ref)."""
    pol = _policy.resolve(policy, backend)
    if pol.backend != "pallas":
        return _ref.attention_fwd_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset)
    b_, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    if block is None and pol.autotune == "cached":
        block = _tcache.get_cache().get_flash(tq, tk, d, q.dtype, pol)
    if block is not None:
        bq, bk = block.bq, block.bk
    o, lse = _fa.flash_attention(
        _flat_heads(q), _flat_heads(k), _flat_heads(v),
        group=h // hkv, causal=causal, window=window,
        q_offset=_per_head(q_offset, h), bq=bq, bk=bk,
        interpret=pol.resolved_interpret, return_lse=True)
    return (o.reshape(b_, h, tq, d).transpose(0, 2, 1, 3),
            lse.reshape(b_, h, tq))


@register_op("flash_attention_bwd", backend="xla")
def _flash_bwd_xla(q, k, v, o, do, lse, *, policy, causal, window,
                   q_offset, block):
    return _ref.attention_bwd_ref(
        q, k, v, o, do, lse, causal=causal, window=window,
        q_offset=q_offset)


@register_op("flash_attention_bwd", backend="pallas")
def _flash_bwd_pallas(q, k, v, o, do, lse, *, policy, causal, window,
                      q_offset, block):
    b_, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    g = h // hkv
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_flash_bwd(tq, tk, d, q.dtype, policy)
    dq, dk, dv = _fa.flash_attention_bwd(
        _flat_heads(q), _flat_heads(k), _flat_heads(v),
        _flat_heads(o), _flat_heads(do), lse.reshape(b_ * h, tq),
        group=g, causal=causal, window=window,
        q_offset=_per_head(q_offset, h), block=block,
        interpret=policy.resolved_interpret)
    dq = dq.reshape(b_, h, tq, d).transpose(0, 2, 1, 3)
    # the kernel returns per-QUERY-head dK/dV (it cannot revisit output
    # blocks across the GQA fan-in); the group-sum happens here, in f32
    dk = dk.reshape(b_, hkv, g, tk, d).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv.reshape(b_, hkv, g, tk, d).sum(axis=2).transpose(0, 2, 1, 3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_attention_bwd(
    q: jnp.ndarray,            # [B, Tq, H, D]
    k: jnp.ndarray,            # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    o: jnp.ndarray,            # [B, Tq, H, D]  forward output
    do: jnp.ndarray,           # [B, Tq, H, D]  output cotangent
    lse: jnp.ndarray,          # [B, H, Tq] f32 forward residual
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,
    policy: Policy | None = None,
    backend: str | None = None,
    block: blocking.FlashBlockConfig | None = None,
):
    """Recompute-style attention backward: (dq, dk, dv) from the saved
    (o, lse) residuals — S/P never hit HBM on the pallas backend."""
    pol = _policy.resolve(policy, backend)
    impl = _registry.get_impl("flash_attention_bwd", pol.backend)
    return impl(q, k, v, o, do, lse, policy=pol, causal=causal,
                window=window, q_offset=q_offset, block=block)


@register_op("flash_decode", backend="xla")
def _flash_decode_xla(q, k, v, *, policy, pos, window, bk, block):
    # the fwd_ref composition (not attention_ref): its exp(S - lse) form
    # zeroes fully-masked rows, so inactive slots (pos < 0) agree with
    # the kernel's zero output instead of softmaxing over -1e30 logits.
    o, _ = _ref.attention_fwd_ref(
        q, k, v, causal=True, window=window, q_offset=pos)
    return o


@register_op("flash_decode", backend="pallas")
def _flash_decode_pallas(q, k, v, *, policy, pos, window, bk, block):
    b_, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_flash_decode(tk, d, q.dtype, policy)
    if block is not None:
        bk = block.bk
    o = _fa.flash_decode(
        _flat_heads(q), _flat_heads(k), _flat_heads(v),
        group=h // hkv, window=window, pos=_per_head(pos, h), bk=bk,
        interpret=policy.resolved_interpret)
    return o.reshape(b_, h, tq, d).transpose(0, 2, 1, 3)


def flash_decode(
    q: jnp.ndarray,            # [B, 1, H, D]  one new token per slot
    k: jnp.ndarray,            # [B, Tk, Hkv, D]  the KV cache
    v: jnp.ndarray,
    *,
    pos=0,                     # scalar, or (B,) per-slot depth vector
    window: int | None = None,
    policy: Policy | None = None,
    backend: str | None = None,
    bk: int = 512,
    block: blocking.FlashBlockConfig | None = None,
) -> jnp.ndarray:
    """Decode-specialized attention: each slot's query attends its
    cache prefix [0, pos] (kv_len = pos + 1). The pallas backend streams
    only the K/V blocks covering the prefix; slots with pos < 0 are
    inactive and return finite garbage the engine discards."""
    assert q.shape[1] == 1, f"flash_decode is q_len=1 only: {q.shape}"
    pol = _policy.resolve(policy, backend)
    impl = _registry.get_impl("flash_decode", pol.backend)
    return impl(q, k, v, policy=pol, pos=pos, window=window, bk=bk,
                block=block)


@register_op("flash_decode_paged", backend="xla")
def _flash_decode_paged_xla(q, kp, vp, table, *, policy, pos, window,
                            ks, vs, bk, block):
    return _ref.flash_decode_paged_ref(
        q, kp, vp, table, pos=pos, window=window, ks=ks, vs=vs)


@register_op("flash_decode_paged", backend="pallas")
def _flash_decode_paged_pallas(q, kp, vp, table, *, policy, pos, window,
                               ks, vs, bk, block):
    b_, tq, h, d = q.shape
    ps = kp.shape[1]
    hkv = kp.shape[2]
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_flash_decode_paged(
            ps, d, q.dtype, policy)
    if block is not None:
        bk = block.bk
    o = _fa.flash_decode_paged(
        q[:, 0], kp, vp, table, group=h // hkv, window=window, pos=pos,
        ks=ks, vs=vs, bk=bk, interpret=policy.resolved_interpret)
    return o[:, None]


def flash_decode_paged(
    q: jnp.ndarray,            # [B, 1, H, D]  one new token per slot
    kp: jnp.ndarray,           # [P, page_size, Hkv, D]  K page pool
    vp: jnp.ndarray,           # [P, page_size, Hkv, D]  V page pool
    table: jnp.ndarray,        # [B, pages_per_slot] int32; -1 unmapped
    *,
    pos=0,                     # scalar, or (B,) per-slot depth vector
    window: int | None = None,
    ks: jnp.ndarray | None = None,    # [P, Hkv, page_size] f32 scales
    vs: jnp.ndarray | None = None,    # (int8 pools only)
    policy: Policy | None = None,
    backend: str | None = None,
    bk: int | None = None,
    block: blocking.FlashBlockConfig | None = None,
) -> jnp.ndarray:
    """flash_decode against a paged KV pool (serving.kv_pool layout):
    slot b's logical page j lives at pool index table[b, j]. The pallas
    backend gathers pages through scalar-prefetched table rows and —
    for int8 pools — dequantizes on the f32 accumulator in-kernel; the
    xla backend is the gather + masked-softmax composition
    (ref.flash_decode_paged_ref), conformance-tested per backend in
    tests/test_property.py. Same pos/window/inactive-slot contract as
    flash_decode."""
    assert q.shape[1] == 1, \
        f"flash_decode_paged is q_len=1 only: {q.shape}"
    assert kp.shape == vp.shape and kp.ndim == 4, (kp.shape, vp.shape)
    assert (ks is None) == (vs is None)
    if ks is not None:
        assert kp.dtype == jnp.int8, \
            f"scale planes supplied for a {kp.dtype} pool"
    pol = _policy.resolve(policy, backend)
    impl = _registry.get_impl("flash_decode_paged", pol.backend)
    return impl(q, kp, vp, table, policy=pol, pos=pos, window=window,
                ks=ks, vs=vs, bk=bk, block=block)


# ----------------------------------------------------------------------
# SSD (Mamba-2 state-space duality)
# ----------------------------------------------------------------------

@register_op("ssd", backend="xla")
def _ssd_xla(x, a, b, c, *, policy, chunk, init_state, block):
    return _ssd.ssd_chunked(x, a, b, c, chunk, init_state=init_state)


@register_op("ssd", backend="naive")
def _ssd_naive(x, a, b, c, *, policy, chunk, init_state, block):
    return _ref.ssd_ref(x, a, b, c, chunk, init_state=init_state)


@register_op("ssd", backend="pallas")
def _ssd_pallas_impl(x, a, b, c, *, policy, chunk, init_state, block):
    p = x.shape[-1]
    n = b.shape[-1]
    served = False
    if block is None and policy.autotune == "cached":
        block = _tcache.get_cache().get_ssd(chunk, p, n, x.dtype, policy)
        served = block is not None
    ok = (block is not None and block.q > 0 and chunk % block.q == 0
          and (block.bp > 0 and p % block.bp == 0 or block.bp == p))
    if not ok:
        if block is not None and not served:
            raise ValueError(f"invalid ssd block config {block} for "
                             f"chunk={chunk}, p={p}")
        block = blocking.choose_ssd_config(
            chunk, p, n, jnp.dtype(x.dtype).itemsize, policy.chip)
    # the execution chunk may subdivide the model chunk: SSD chunking
    # is algebraically exact, so any divisor computes the same function.
    return _ssd.ssd_pallas(
        x, a, b, c, block.q, init_state=init_state, block_p=block.bp,
        interpret=policy.resolved_interpret)


def ssd(
    x: jnp.ndarray,            # (B, L, H, P) — dt-scaled inputs
    a: jnp.ndarray,            # (B, L, H)    — dt*A log decays
    b: jnp.ndarray,            # (B, L, G, N)
    c: jnp.ndarray,            # (B, L, G, N)
    chunk: int,
    init_state: jnp.ndarray | None = None,   # (B, H, P, N)
    *,
    policy: Policy | None = None,
    backend: str | None = None,        # deprecated string shim
    block: blocking.SSDBlockConfig | None = None,
    chip: hw.ChipSpec | None = None,
):
    """Chunked SSD scan (Mamba-2 dual form): returns
    ``(y (B, L, H, P) in x.dtype, final_state (B, H, P, N) f32)``.

    The inter-chunk state is carried in f32 on every backend (cast at
    the boundary), and `init_state` seeds the recurrence — carried-state
    chunked prefill composes exactly. The pallas backend keeps the
    per-chunk decay mask and CB score matrices VMEM-resident
    (kernels.ssd); `chunk` is the model's configured chunk, while the
    kernel's *execution* chunk/tiling comes from the autotuner cache
    (policy.autotune == "cached") or the static chooser — any divisor
    computes the same function. Training flows through the core.ssd
    chokepoint, whose custom VJP differentiates the unfused composition.
    """
    if x.ndim != 4 or a.ndim != 3 or b.ndim != 4 or c.ndim != 4:
        raise ValueError(f"ssd expects x(B,L,H,P) a(B,L,H) b/c(B,L,G,N); "
                         f"got {x.shape}, {a.shape}, {b.shape}, {c.shape}")
    bsz, l, h, p = x.shape
    g, n = b.shape[-2:]
    if a.shape != (bsz, l, h):
        raise ValueError(f"a shape {a.shape} incompatible with x {x.shape}")
    if b.shape != (bsz, l, g, n) or c.shape != b.shape:
        raise ValueError(f"b/c shapes {b.shape}/{c.shape} must match")
    if h % g:
        raise ValueError(f"heads {h} not divisible by groups {g}")
    if chunk <= 0 or l % chunk:
        raise ValueError(f"seq len {l} not divisible by chunk {chunk}")
    if init_state is not None and init_state.shape != (bsz, h, p, n):
        raise ValueError(f"init_state shape {init_state.shape} != "
                         f"{(bsz, h, p, n)}")
    pol = _policy.resolve(policy, backend)
    if chip is not None and chip is not pol.chip:
        pol = pol.replace(chip=chip)
    impl = _registry.get_impl("ssd", pol.backend)
    return impl(x, a, b, c, policy=pol, chunk=chunk, init_state=init_state,
                block=block)


# ----------------------------------------------------------------------
# compat shims — the ONLY layer that still interprets backend strings.
# Everything below exists so pre-Policy call sites keep working; new
# code constructs a Policy (core.policy) instead.
# ----------------------------------------------------------------------

#: Deprecated alias: the legacy string spellings `Policy.from_backend`
#: accepts. Kept so old `choices=kops.MATMUL_BACKENDS` CLIs still run.
MATMUL_BACKENDS = _policy.LEGACY_BACKEND_NAMES


def resolve_tuned(backend: str) -> str:
    """Deprecated: "tuned(_interpret)" executes the tiled kernel; the
    typed equivalent is Policy.from_backend(backend).kernel_fingerprint
    (cache entries stay keyed by execution backend so interpreter
    timings never leak into compiled-TPU decisions)."""
    _policy.warn_deprecated(
        "resolve_tuned",
        "kernels.ops.resolve_tuned is deprecated; use "
        "Policy.from_backend(name).kernel_fingerprint")
    return "pallas_interpret" if backend.endswith("interpret") else "pallas"
