"""jit'd wrappers around the Pallas kernels.

Handles the plumbing the kernels themselves keep out of scope: backend
selection, shape padding to block multiples, block-size choice via
core.blocking (the paper's shared-memory sizing argument), and the
interpret-mode fallback used on this CPU-only container.

Backends:
  xla               jnp.matmul — what the multi-pod dry-run compiles
  pallas            tiled Pallas kernel, compiled for TPU (Listing 4)
  pallas_interpret  same kernel, interpreter — CPU validation
  naive             hierarchy-blind Pallas kernel (Listing 3)
  naive_interpret   its interpreter twin
  tuned             tiled kernel with tile sizes served from the
                    autotuner cache (repro.tuning); falls back to the
                    static core.blocking chooser on a cache miss or
                    hardware-fingerprint mismatch
  tuned_interpret   its interpreter twin (cache keyed separately)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import blocking, hw
from repro.kernels import elementwise as _ew
from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import matmul_naive as _mmn
from repro.kernels import ref as _ref
from repro.tuning import cache as _tcache

MATMUL_BACKENDS = (
    "xla", "pallas", "pallas_interpret", "naive", "naive_interpret",
    "tuned", "tuned_interpret",
)


def resolve_tuned(backend: str) -> str:
    """tuned(_interpret) executes the tiled kernel; cache entries are
    keyed by the execution backend so interpreter timings never leak
    into compiled-TPU decisions."""
    return "pallas_interpret" if backend.endswith("interpret") else "pallas"


def _pad2(x: jnp.ndarray, m_to: int, n_to: int) -> jnp.ndarray:
    m, n = x.shape
    if m == m_to and n == n_to:
        return x
    return jnp.pad(x, ((0, m_to - m), (0, n_to - n)))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _usable_block(block, served: bool) -> bool:
    """Discard degenerate tile configs (a corrupt cache entry must fall
    back to the static chooser, not crash the padding arithmetic)."""
    if block is None:
        return False
    ok = block.bm > 0 and block.bn > 0 and block.bk > 0
    if not ok and not served:
        raise ValueError(f"invalid block config {block}")
    return ok


def _epilogue_operand(epilogue, bias, residual, m, n, mp, np_):
    """Validate + pad the flush-phase operand to the padded tile grid.
    The operand keeps its own dtype — the kernel casts it to the
    accumulator dtype, mirroring the unfused ref.epilogue_ref cast, so
    a residual/bias wider than the inputs loses no precision."""
    if epilogue == "none":
        assert bias is None and residual is None, \
            "bias/residual operands need an epilogue"
        return None
    if epilogue == "residual":
        assert residual is not None and residual.shape == (m, n), epilogue
        return _pad2(residual, mp, np_)
    assert epilogue in _mm.EPILOGUES, epilogue
    assert bias is not None, f"epilogue={epilogue} needs bias="
    e = bias.reshape(1, -1)
    assert e.shape == (1, n), (bias.shape, n)
    return _pad2(e, 1, np_)


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    backend: str = "xla",
    out_dtype=None,
    block: blocking.BlockConfig | None = None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    epilogue: str = "none",
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """2D real GEMM through the selected backend, padding as needed.

    epilogue/bias/residual select a fused flush (kernels.matmul
    EPILOGUES): the Pallas backends apply it inside the kernel on the
    f32 accumulator; xla and naive apply the same composition unfused
    (ref.epilogue_ref), so every backend computes the same function.
    """
    assert a.ndim == 2 and b.ndim == 2, (a.shape, b.shape)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = out_dtype or a.dtype

    if backend == "xla":
        y = _ref.matmul_ref(a, b, out_dtype=out_dtype)
        return _ref.epilogue_ref(y, epilogue, bias, residual)

    served = False
    if backend.startswith("tuned"):
        backend = resolve_tuned(backend)
        if block is None:
            block = _tcache.get_cache().get_matmul(
                m, n, k, a.dtype, backend, epilogue=epilogue)
            served = block is not None
            # miss / fingerprint mismatch -> block stays None and the
            # static chooser below picks the paper's default tiles.

    interpret = backend.endswith("interpret")
    itemsize = jnp.dtype(a.dtype).itemsize

    if backend.startswith("naive"):
        sub = chip.sublane(itemsize)
        mp, np_ = _round_up(m, sub), _round_up(n, chip.lane)
        out = _mmn.matmul_naive(
            _pad2(a, mp, k), _pad2(b, k, np_),
            out_dtype=out_dtype, interpret=interpret)[:m, :n]
        return _ref.epilogue_ref(out, epilogue, bias, residual)

    if not _usable_block(block, served):
        block = blocking.choose_block_config(m, n, k, itemsize, chip)
    # padding to block multiples guarantees the kernel's clamp
    # re-validation passes: every dim is a multiple of its tile edge.
    mp = _round_up(m, block.bm)
    np_ = _round_up(n, block.bn)
    kp = _round_up(k, block.bk)
    e = _epilogue_operand(epilogue, bias, residual, m, n, mp, np_)
    out = _mm.matmul_tiled(
        _pad2(a, mp, kp), _pad2(b, kp, np_),
        bm=block.bm, bn=block.bn, bk=block.bk,
        out_dtype=out_dtype, interpret=interpret,
        epilogue=epilogue, epilogue_operand=e)
    return out[:m, :n]


def gated_matmul(
    a: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    *,
    backend: str = "xla",
    out_dtype=None,
    block: blocking.BlockConfig | None = None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
) -> jnp.ndarray:
    """silu(a @ w_gate) * (a @ w_up) — the SwiGLU hidden phase.

    Pallas backends run the dual-GEMM kernel (one A stream, two weight
    operands, zero HBM intermediates); xla/naive compose it unfused.
    Tiles come from the gated autotuner cache entries or the n_rhs=2
    static chooser (doubled B-side working set).
    """
    assert a.ndim == w_gate.ndim == w_up.ndim == 2
    m, k = a.shape
    assert w_gate.shape == w_up.shape == (k, w_gate.shape[1])
    n = w_gate.shape[1]
    out_dtype = out_dtype or a.dtype

    if backend == "xla" or backend.startswith("naive"):
        g = matmul(a, w_gate, backend=backend, out_dtype=out_dtype,
                   chip=chip)
        u = matmul(a, w_up, backend=backend, out_dtype=out_dtype, chip=chip)
        return (jax.nn.silu(g) * u).astype(out_dtype)

    served = False
    if backend.startswith("tuned"):
        backend = resolve_tuned(backend)
        if block is None:
            block = _tcache.get_cache().get_gated(m, n, k, a.dtype, backend)
            served = block is not None

    interpret = backend.endswith("interpret")
    itemsize = jnp.dtype(a.dtype).itemsize
    if not _usable_block(block, served):
        block = blocking.choose_block_config(m, n, k, itemsize, chip,
                                             n_rhs=2)
    mp = _round_up(m, block.bm)
    np_ = _round_up(n, block.bn)
    kp = _round_up(k, block.bk)
    out = _mm.gated_matmul_tiled(
        _pad2(a, mp, kp), _pad2(w_gate, kp, np_), _pad2(w_up, kp, np_),
        bm=block.bm, bn=block.bn, bk=block.bk,
        out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]


def add(a, b, *, backend: str = "xla", interpret: bool | None = None):
    """interpret=None derives interpreter mode from the backend string;
    an explicit bool overrides it (e.g. force-interpret on CPU)."""
    if backend == "xla":
        return _ref.add_ref(a, b)
    if interpret is None:
        interpret = backend.endswith("interpret")
    return _ew.binary_op(a, b, "add", interpret=interpret)


def sub(a, b, *, backend: str = "xla", interpret: bool | None = None):
    if backend == "xla":
        return _ref.sub_ref(a, b)
    if interpret is None:
        interpret = backend.endswith("interpret")
    return _ew.binary_op(a, b, "sub", interpret=interpret)


def flash_attention(
    q: jnp.ndarray,            # [B, Tq, H, D]
    k: jnp.ndarray,            # [B, Tk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,                # scalar, or (B,) per-row vector (decode)
    backend: str = "xla",
    bq: int = 256,
    bk: int = 512,
    block: blocking.FlashBlockConfig | None = None,
) -> jnp.ndarray:
    """Layout-normalising wrapper: model code uses [B, T, H, D]."""
    if backend == "xla":
        return _ref.attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset)
    b_, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    if jnp.asarray(q_offset).ndim == 1:
        # per-batch offsets -> per-(batch*head) rows of the flat layout
        q_offset = jnp.repeat(jnp.asarray(q_offset, jnp.int32), h)
    if backend.startswith("tuned"):
        backend = resolve_tuned(backend)
        if block is None:
            block = _tcache.get_cache().get_flash(tq, tk, d, q.dtype, backend)
    if block is not None:
        bq, bk = block.bq, block.bk
    g = h // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b_ * h, tq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b_ * hkv, tk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b_ * hkv, tk, d)
    o = _fa.flash_attention(
        qf, kf, vf, group=g, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk,
        interpret=backend.endswith("interpret"))
    return o.reshape(b_, h, tq, d).transpose(0, 2, 1, 3)
