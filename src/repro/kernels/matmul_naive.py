"""Hierarchy-blind Pallas GEMM — the TPU analogue of the paper's
Listing 3 (the 'nieoptymalna' version).

The CUDA original gives every thread one output element and streams the
full row of A / column of B from *global* memory with zero cross-thread
reuse. A literal port is impossible (Pallas kernels compute on VMEM
refs), so the honest analogue keeps the structural sin — *no k-blocking
and minimal staging reuse* — within TPU constraints:

  * grid is (M/bm, N/bn) only; each cell stages the FULL (bm, K) strip
    of A and (K, bn) strip of B;
  * tiles are the minimum hardware shape (sublane x lane), so the reuse
    factor per loaded byte is bm (=8 for f32) vs the tiled kernel's
    256+ — matching the paper's 'one row / one column per thread'
    traffic ratio as closely as the ISA allows;
  * it simply cannot run for large K (the strips overflow VMEM), which
    is the paper's scalability argument against Listing 3 made physical.

Used only by benchmarks (Fig. 8 before/after) and tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _naive_kernel(a_ref, b_ref, o_ref, *, out_dtype):
    acc_dtype = jnp.float64 if a_ref.dtype == jnp.float64 else jnp.float32
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_dtype
    ).astype(out_dtype)


def matmul_naive(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb
    if out_dtype is None:
        out_dtype = a.dtype
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    kernel = functools.partial(_naive_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, ka), lambda i, j: (i, 0)),
            pl.BlockSpec((ka, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, b)
