"""Elementwise Pallas kernels — the paper's matrix add/sub study (Fig 9).

The paper's point is that these ops are bandwidth-bound and gain nothing
from the accelerator; we implement them anyway (they are real framework
substrate — residual adds, bias adds) and let the benchmark demonstrate
the asymmetry via core.intensity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binary_kernel(a_ref, b_ref, o_ref, *, op: str):
    a, b = a_ref[...], b_ref[...]
    if op == "add":
        o_ref[...] = a + b
    elif op == "sub":
        o_ref[...] = a - b
    elif op == "mul":
        o_ref[...] = a * b
    else:
        raise ValueError(op)


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0, 0] * x_ref[...] + y_ref[...]


def binary_op(
    a: jnp.ndarray,
    b: jnp.ndarray,
    op: str = "add",
    *,
    bm: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """C = A (op) B over 2D arrays, row-blocked."""
    assert a.shape == b.shape and a.ndim == 2
    m, n = a.shape
    bm = min(bm, m)
    assert m % bm == 0
    kernel = functools.partial(_binary_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def axpy(
    alpha: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """alpha*x + y (scalar alpha prefetched once, not per-block)."""
    assert x.shape == y.shape and x.ndim == 2
    m, n = x.shape
    bm = min(bm, m)
    assert m % bm == 0
    alpha = jnp.asarray(alpha, x.dtype).reshape((1, 1))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(alpha, x, y)
