"""Flash (online-softmax) attention as a Pallas TPU kernel.

This is the paper's core insight — stage tiles in scratchpad memory and
maximise reuse before touching HBM — applied to the framework's second
GEMM-shaped hot spot. The S = QK^T matrix is never materialised in HBM;
(bq, d) query tiles stay resident in VMEM while (bk, d) key/value tiles
stream through, with the running max/denominator kept in VMEM scratch
(the 'register accumulator' of Listing 4, generalised to softmax).

Supports causal masking, sliding windows (Mixtral), and GQA via an
index-map trick: query head h reads kv head h // group, so kv tensors
are never physically repeated.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, qo_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_kv: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None,
):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # q_offset streams in as data (one scalar per B*H row) so a single
    # compiled kernel serves every decode depth — and, with a per-row
    # vector, a continuous batch of requests at heterogeneous depths.
    q_start = pl.program_id(1) * bq + qo_ref[0, 0]
    k_start = kv_i * bk

    # Block-level skip: entirely above the causal diagonal or entirely
    # left of the sliding window -> no compute (DMA still streams, the
    # cost model in core/blocking charges it; see EXPERIMENTS §Perf).
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                               # (bq, LANES)
        s_max = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, s_max)                # broadcast
        alpha = jnp.exp(m_prev - m_new)                   # (bq, LANES)
        p = jnp.exp(s - m_new[:, :1])                     # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(
            p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,           # [B*H,  Tq, D]
    k: jnp.ndarray,           # [B*Hkv, Tk, D]
    v: jnp.ndarray,           # [B*Hkv, Tk, D]
    *,
    group: int = 1,           # H // Hkv
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset=0,               # scalar, or (B*H,) per-row vector (decode)
    bq: int = 256,
    bk: int = 512,
    block=None,
    interpret: bool = False,
) -> jnp.ndarray:
    # `block` (core.blocking.FlashBlockConfig — e.g. an autotuner-cache
    # winner) overrides the bq/bk defaults.
    if block is not None:
        bq, bk = block.bq, block.bk
    bh, tq, d = q.shape
    bhkv, tk, dk = k.shape
    assert d == dk and v.shape == k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    n_kv = tk // bk

    # Per-row query offsets ride along as a (bh, 1) int32 operand; a
    # scalar broadcasts to all rows (2-D because TPU scalars live in
    # SMEM as (1, 1) blocks).
    qo = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1, 1), (bh, 1))

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window)

    if _HAS_PLTPU:
        scratch = [
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ]
    else:  # pragma: no cover
        scratch = []

    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    qo_spec_kw = {"memory_space": pltpu.SMEM} if _HAS_PLTPU else {}
    return pl.pallas_call(
        kernel,
        grid=(bh, tq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, 1), lambda h, i, j: (h, 0), **qo_spec_kw),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(q, k, v, qo)
