"""Flash (online-softmax) attention as a Pallas TPU kernel.

This is the paper's core insight — stage tiles in scratchpad memory and
maximise reuse before touching HBM — applied to the framework's second
GEMM-shaped hot spot. The S = QK^T matrix is never materialised in HBM;
(bq, d) query tiles stay resident in VMEM while (bk, d) key/value tiles
stream through, with the running max/denominator kept in VMEM scratch
(the 'register accumulator' of Listing 4, generalised to softmax).

Supports causal masking, sliding windows (Mixtral), and GQA via an
index-map trick: query head h reads kv head h // group, so kv tensors
are never physically repeated.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

_NEG_INF = -1e30
# logsumexp sentinel for a fully-masked row (inactive decode slot): the
# backward recomputes P = exp(S - lse), and S <= ~1e30, so +1e30 forces
# P = 0 — the row contributes nothing to any gradient.
_LSE_EMPTY = 1e30
_LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, qo_ref, o_ref, *rest,
    n_kv: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None, save_lse: bool,
):
    if save_lse:
        lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        acc_ref, m_ref, l_ref = rest
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # q_offset streams in as data (one scalar per B*H row) so a single
    # compiled kernel serves every decode depth — and, with a per-row
    # vector, a continuous batch of requests at heterogeneous depths.
    q_start = pl.program_id(1) * bq + qo_ref[0, 0]
    k_start = kv_i * bk

    # Block-level skip: entirely above the causal diagonal or entirely
    # left of the sliding window -> no compute (DMA still streams, the
    # cost model in core/blocking charges it; see EXPERIMENTS §Perf).
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                               # (bq, LANES)
        s_max = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, s_max)                # broadcast
        alpha = jnp.exp(m_prev - m_new)                   # (bq, LANES)
        p = jnp.exp(s - m_new[:, :1])                     # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(
            p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / lsafe).astype(o_ref.dtype)
        if save_lse:
            # lse = m + log(l) in the scaled-logit units the backward
            # recomputes S in; empty rows get the +inf sentinel.
            lse = jnp.where(l > 0.0,
                            m_ref[:, :1] + jnp.log(lsafe), _LSE_EMPTY)
            lse_ref[0] = lse[:, 0]


def flash_attention(
    q: jnp.ndarray,           # [B*H,  Tq, D]
    k: jnp.ndarray,           # [B*Hkv, Tk, D]
    v: jnp.ndarray,           # [B*Hkv, Tk, D]
    *,
    group: int = 1,           # H // Hkv
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset=0,               # scalar, or (B*H,) per-row vector (decode)
    bq: int = 256,
    bk: int = 512,
    block=None,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Returns o, or (o, lse) with the per-row logsumexp (bh, tq) f32
    residual the recompute-style backward consumes (return_lse=True)."""
    # `block` (core.blocking.FlashBlockConfig — e.g. an autotuner-cache
    # winner) overrides the bq/bk defaults.
    if block is not None:
        bq, bk = block.bq, block.bk
    bh, tq, d = q.shape
    bhkv, tk, dk = k.shape
    assert d == dk and v.shape == k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    n_kv = tk // bk

    # Per-row query offsets ride along as a (bh, 1) int32 operand; a
    # scalar broadcasts to all rows (2-D because TPU scalars live in
    # SMEM as (1, 1) blocks).
    qo = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1, 1), (bh, 1))

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window, save_lse=return_lse)

    if _HAS_PLTPU:
        scratch = [
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ]
    else:  # pragma: no cover
        scratch = []

    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    o_spec = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))
    o_shape = jax.ShapeDtypeStruct((bh, tq, d), q.dtype)
    out_specs = o_spec
    out_shape = o_shape
    if return_lse:
        out_specs = [o_spec, pl.BlockSpec((1, bq), lambda h, i, j: (h, i))]
        out_shape = [o_shape, jax.ShapeDtypeStruct((bh, tq), jnp.float32)]

    qo_spec_kw = {"memory_space": pltpu.SMEM} if _HAS_PLTPU else {}
    out = pl.pallas_call(
        kernel,
        grid=(bh, tq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, 1), lambda h, i, j: (h, 0), **qo_spec_kw),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(q, k, v, qo)
    if return_lse:
        return out[0], out[1]
    return out


# ----------------------------------------------------------------------
# Recompute-style backward (no S matrix in HBM)
# ----------------------------------------------------------------------
#
# With qs = q * scale and the saved per-row lse = m + log(l):
#
#     S  = qs K^T              P  = exp(S - lse)      (masked entries 0)
#     dV = P^T dO              dP = dO V^T
#     dS = P * (dP - D),       D  = rowsum(dO * O)    (computed in XLA)
#     dK = dS^T qs             dQ = scale * (dS K)
#
# Two sweeps so every output block is revisited only along the LAST
# ("arbitrary") grid dim: sweep 1 holds (bk, d) dK/dV accumulators in
# VMEM while q/dO/lse/D blocks stream past; sweep 2 mirrors it for dQ.
# S and P are recomputed in VMEM from the streamed tiles — they never
# existed in HBM in the forward and never do here either.


def _bwd_tiles(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               q_start, k_start, bq, bk, scale, causal, window):
    """Shared recompute of the (bq, bk) P / dS tiles for both sweeps."""
    q = q_ref[0].astype(jnp.float32) * scale              # (bq, d) scaled
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)                      # (bk, d)
    do = do_ref[0].astype(jnp.float32)                    # (bq, d)
    lse = lse_ref[0][:, None]                             # (bq, 1)
    delta = delta_ref[0][:, None]                         # (bq, 1)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bq, bk)
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)            # (bq, bk)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bq, bk)
    ds = p * (dp - delta)                                 # (bq, bk)
    return q, k, do, p, ds


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qo_ref,
    dk_ref, dv_ref, dk_acc, dv_acc,
    *, n_q: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None,
):
    q_i = pl.program_id(2)

    @pl.when(q_i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = q_i * bq + qo_ref[0, 0]
    k_start = pl.program_id(1) * bk
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q, _, do, p, ds = _bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            q_start, k_start, bq, bk, scale, causal, window)
        dv_acc[...] += jax.lax.dot_general(             # P^T dO  (bk, d)
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(             # dS^T qs (bk, d)
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(q_i == n_q - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qo_ref,
    dq_ref, dq_acc,
    *, n_kv: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None,
):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = pl.program_id(1) * bq + qo_ref[0, 0]
    k_start = kv_i * bk
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        _, k, _, _, ds = _bwd_tiles(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            q_start, k_start, bq, bk, scale, causal, window)
        dq_acc[...] += jax.lax.dot_general(             # dS K  (bq, d)
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        dq_ref[0] = (dq_acc[...] * scale).astype(dq_ref.dtype)


def flash_attention_bwd(
    q: jnp.ndarray,           # [B*H,  Tq, D]
    k: jnp.ndarray,           # [B*Hkv, Tk, D]
    v: jnp.ndarray,           # [B*Hkv, Tk, D]
    o: jnp.ndarray,           # [B*H,  Tq, D]  forward output
    do: jnp.ndarray,          # [B*H,  Tq, D]  output cotangent
    lse: jnp.ndarray,         # [B*H,  Tq] f32 forward logsumexp residual
    *,
    group: int = 1,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset=0,
    bq: int = 256,
    bk: int = 512,
    block=None,
    interpret: bool = False,
):
    """dQ/dK/dV in f32. dK/dV come back PER QUERY HEAD ([B*H, Tk, D]) —
    Pallas forbids revisiting an output block across non-consecutive
    grid steps, so the GQA group-sum over the h // group fan-in happens
    in the caller (kernels.ops), not here."""
    if block is not None:
        bq, bk = block.bq, block.bk
    bh, tq, d = q.shape
    bhkv, tk, dk_ = k.shape
    assert d == dk_ and v.shape == k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    assert o.shape == q.shape == do.shape
    assert lse.shape == (bh, tq), (lse.shape, bh, tq)
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    n_q, n_kv = tq // bq, tk // bk

    qo = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1, 1), (bh, 1))
    lse = lse.astype(jnp.float32)
    # D = rowsum(dO * O): one cheap XLA reduction instead of a third
    # sweep — (bh, tq) f32 streams into both kernels like lse does.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    qd_spec = pl.BlockSpec((1, bq, d), lambda h, j, i: (h, i, 0))
    row_spec = pl.BlockSpec((1, bq), lambda h, j, i: (h, i))
    kv_spec = pl.BlockSpec((1, bk, d), lambda h, j, i, g=group: (h // g, j, 0))
    qo_spec_kw = {"memory_space": pltpu.SMEM} if _HAS_PLTPU else {}
    qo_spec = pl.BlockSpec((1, 1), lambda h, j, i: (h, 0), **qo_spec_kw)

    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    dkv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, n_q=n_q, bq=bq, bk=bk, scale=scale,
            causal=causal, window=window),
        grid=(bh, n_kv, n_q),
        in_specs=[qd_spec, kv_spec, kv_spec, qd_spec, row_spec, row_spec,
                  qo_spec],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda h, j, i: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, i: (h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tk, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, tk, d), jnp.float32),
        ],
        scratch_shapes=([pltpu.VMEM((bk, d), jnp.float32)] * 2
                        if _HAS_PLTPU else []),
        interpret=interpret,
        **params,
    )(q, k, v, do, lse, delta, qo)
    dk, dv = dkv

    # Sweep 2 swaps the roles: grid (bh, n_q, n_kv), so the same specs
    # serve with (j, i) now meaning (q-block, kv-block).
    qd_spec2 = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))
    row_spec2 = pl.BlockSpec((1, bq), lambda h, i, j: (h, i))
    kv_spec2 = pl.BlockSpec((1, bk, d),
                            lambda h, i, j, g=group: (h // g, j, 0))
    qo_spec2 = pl.BlockSpec((1, 1), lambda h, i, j: (h, 0), **qo_spec_kw)
    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, n_kv=n_kv, bq=bq, bk=bk, scale=scale,
            causal=causal, window=window),
        grid=(bh, n_q, n_kv),
        in_specs=[qd_spec2, kv_spec2, kv_spec2, qd_spec2, row_spec2,
                  row_spec2, qo_spec2],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), jnp.float32),
        scratch_shapes=([pltpu.VMEM((bq, d), jnp.float32)]
                        if _HAS_PLTPU else []),
        interpret=interpret,
        **params,
    )(q, k, v, do, lse, delta, qo)
    return dq, dk, dv


# ----------------------------------------------------------------------
# Decode-specialized kernel (q_len = 1 against a long cache)
# ----------------------------------------------------------------------

def _flash_decode_kernel(
    q_ref, k_ref, v_ref, pos_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_kv: int, bk: int, scale: float, window: int | None,
):
    kv_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    k_start = kv_i * bk

    # THE decode win: only blocks intersecting the valid prefix
    # [max(0, pos-window+1), pos] run — a slot at depth 100 in a 4096
    # cache touches one K/V block, not eight. pos < 0 (inactive slot)
    # skips every block; the flush's l == 0 guard keeps o finite.
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (1, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos <= pos                  # kv_len = pos + 1 prefix
        if window is not None:
            mask &= k_pos > pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                               # (1, LANES)
        s_max = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, s_max)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,           # [B*H, 1, D]  one new token per row
    k: jnp.ndarray,           # [B*Hkv, Tk, D]  the cache, max_len deep
    v: jnp.ndarray,           # [B*Hkv, Tk, D]
    *,
    group: int = 1,           # H // Hkv
    window: int | None = None,
    scale: float | None = None,
    pos=0,                    # scalar, or (B*H,) per-row depth vector;
                              # valid prefix is keys [0, pos] (causal)
    bk: int = 512,
    block=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """q_len=1 flash attention. Equivalent to causal flash_attention
    with q_offset=pos at tq=1, but grid (B*H, Tk/bk) with per-row
    block-level skip: K/V stream only over the slot's valid prefix
    instead of the whole max_len cache. GQA reads kv row h // group —
    kv heads are never repeated. Rows with pos < 0 (inactive slots)
    produce finite garbage the caller discards."""
    if block is not None:
        bk = block.bk
    bh, tq, d = q.shape
    assert tq == 1, f"flash_decode is q_len=1 only, got tq={tq}"
    bhkv, tk, dk_ = k.shape
    assert d == dk_ and v.shape == k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    scale = scale if scale is not None else d ** -0.5
    bk = min(bk, tk)
    assert tk % bk == 0, (tk, bk)
    n_kv = tk // bk

    pos_op = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (bh, 1))

    if _HAS_PLTPU:
        scratch = [
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, _LANES), jnp.float32),
            pltpu.VMEM((1, _LANES), jnp.float32),
        ]
    else:  # pragma: no cover
        scratch = []

    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        )

    pos_spec_kw = {"memory_space": pltpu.SMEM} if _HAS_PLTPU else {}
    return pl.pallas_call(
        functools.partial(
            _flash_decode_kernel, n_kv=n_kv, bk=bk, scale=scale,
            window=window),
        grid=(bh, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, 1), lambda h, j: (h, 0), **pos_spec_kw),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(q, k, v, pos_op)


# ----------------------------------------------------------------------
# Paged decode kernel (K/V gathered through a slot page table)
# ----------------------------------------------------------------------
#
# The serving cache is a pool of (page_size, Hkv, d) pages shared across
# slots (serving.kv_pool); each slot owns a page-table row mapping its
# logical pages onto pool indices. The table and the per-slot pos vector
# ride in as SCALAR-PREFETCH operands — they land in SMEM before the
# grid runs, so the K/V BlockSpec index maps can dereference them: grid
# step j of slot b streams pool page table[b, j // sub_per_page], one
# K/V page (or bk-sub-tile of it) per step. The dense kernel's
# `k_start <= pos` block skip carries over unchanged — j*bk is still the
# logical key offset — so a shallow slot touches only its own prefix no
# matter where its pages sit in the pool. int8 pools dequantize on the
# f32 accumulator inside the kernel: the per-(position, head) scales
# stream as (P, Hkv, page_size) planes sliced by the same index map.

def _flash_decode_paged_kernel(
    table_ref, pos_ref,            # scalar-prefetch: (B, pp), (B,) SMEM
    *refs,
    n_steps: int, bk: int, scale: float, window: int | None, quant: bool,
):
    if quant:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref \
            = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    k_start = j * bk                  # logical key offset of this step

    # Same skip as the dense decode kernel: pages past the slot's valid
    # prefix [0, pos] never run (pos < 0 skips everything; the flush's
    # l == 0 guard keeps o finite). Unmapped table entries (-1) only
    # occur past the prefix, so the index-map clamp to page 0 is never
    # read by an active step.
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0][:, None]                 # dequant on f32
            v = v * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (1, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos <= pos
        if window is not None:
            mask &= k_pos > pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                               # (1, LANES)
        s_max = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, s_max)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_steps - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_paged(
    q: jnp.ndarray,           # [B, H, D]  one new token per slot
    kp: jnp.ndarray,          # [P, page_size, Hkv, D]  K page pool
    vp: jnp.ndarray,          # [P, page_size, Hkv, D]  V page pool
    table: jnp.ndarray,       # [B, pages_per_slot] int32; -1 = unmapped
    *,
    group: int = 1,           # H // Hkv
    window: int | None = None,
    scale: float | None = None,
    pos=0,                    # scalar, or (B,) per-slot depth vector
    ks: jnp.ndarray | None = None,   # [P, Hkv, page_size] f32 K scales
    vs: jnp.ndarray | None = None,   # [P, Hkv, page_size] f32 V scales
    bk: int | None = None,    # sub-page tile; must divide page_size
    block=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """flash_decode against a paged KV pool: K/V blocks are gathered
    through `table` by the BlockSpec index maps (table + pos are
    scalar-prefetch SMEM operands), one page — or one bk-sub-tile of a
    page — per grid step. Pools may be int8 with per-(position, head)
    f32 scale planes (ks/vs): dequantization happens on the kernel's
    f32 accumulator, so HBM streams one byte per element. Returns
    [B, H, D]; rows with pos < 0 produce finite garbage the caller
    discards (same contract as flash_decode)."""
    if not _HAS_PLTPU:  # pragma: no cover
        raise NotImplementedError(
            "flash_decode_paged needs pallas TPU scalar prefetch "
            "(jax.experimental.pallas.tpu unavailable)")
    if block is not None:
        bk = block.bk
    b, h, d = q.shape
    n_pages, ps, hkv, dk_ = kp.shape
    assert d == dk_ and vp.shape == kp.shape, (q.shape, kp.shape, vp.shape)
    assert h == hkv * group, (h, hkv, group)
    pp = table.shape[1]
    assert table.shape == (b, pp), (table.shape, b)
    quant = ks is not None
    if quant:
        assert vs is not None
        assert ks.shape == vs.shape == (n_pages, hkv, ps), \
            (ks.shape, n_pages, hkv, ps)
    bk = ps if bk is None else min(bk, ps)
    assert ps % bk == 0, (ps, bk)
    spp = ps // bk                    # grid sub-steps per page
    n_steps = pp * spp
    scale = scale if scale is not None else d ** -0.5

    pos_op = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    table = jnp.asarray(table, jnp.int32)

    def page_map(bi, hi, j, t, p, g=group, s=spp):
        # -1 (unmapped) clamps to pool page 0; such steps never run.
        return (jnp.maximum(t[bi, j // s], 0), j % s, hi // g, 0)

    def scale_map(bi, hi, j, t, p, g=group, s=spp):
        return (jnp.maximum(t[bi, j // s], 0), hi // g, j % s)

    in_specs = [
        pl.BlockSpec((1, 1, d), lambda bi, hi, j, t, p: (bi, hi, 0)),
        pl.BlockSpec((1, bk, 1, d), page_map),
        pl.BlockSpec((1, bk, 1, d), page_map),
    ]
    operands = [q, kp, vp]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, bk), scale_map)] * 2
        operands += [ks, vs]

    params = {}
    if not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, j, t, p:
                               (bi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, _LANES), jnp.float32),
            pltpu.VMEM((1, _LANES), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _flash_decode_paged_kernel, n_steps=n_steps, bk=bk,
            scale=scale, window=window, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
        **params,
    )(table, pos_op, *operands)
