"""Tiled Pallas GEMM — the TPU adaptation of the paper's Listing 4 —
plus fused epilogues and the dual-GEMM gated (SwiGLU) variant.

The CUDA original stages BLOCK x BLOCK sub-matrices of A and B into
shared memory, __syncthreads(), FMAs over the block's k range, and
accumulates in a register. The TPU version:

  * the grid is (M/bm, N/bn, K/bk) with k innermost ("arbitrary"
    semantics) — the k loop of Listing 4 becomes the minor grid dim;
  * BlockSpec index maps stage (bm, bk) and (bk, bn) tiles into VMEM —
    Mosaic double-buffers the HBM->VMEM DMA, which replaces the paper's
    explicit __syncthreads() staging discipline;
  * accumulation happens in an f32 VMEM scratch tile (the register
    C_temporary of the paper, grown to a full output tile) and is cast
    to the output dtype on the last k step;
  * jnp.dot inside the kernel body maps onto the 128x128 MXU with
    preferred_element_type=f32.

Fused epilogues extend the paper's staying-in-fast-memory argument to
the operator *chain*: the last-k flush — the only moment the f32
accumulator is in registers anyway — applies bias / activation /
residual before the single HBM write, so the (M, N) intermediate of the
unfused composition never round-trips through HBM. The epilogue operand
(a (1, N) bias row or (M, N) residual) is streamed through its own
BlockSpec. Supported epilogues:

    none       C = A @ B
    bias       C = A @ B + bias
    bias_gelu  C = gelu(A @ B + bias)
    bias_silu  C = silu(A @ B + bias)
    residual   C = A @ B + R

`gated_matmul_tiled` goes one step further for the SwiGLU hot path: one
A tile is staged against TWO weight operands (W_gate, W_up), two f32
accumulators run in parallel, and the flush emits
``silu(A @ Wg) * (A @ Wu)`` in a single pass — both (M, N)
intermediates of the unfused composition are eliminated.

`matmul_q_tiled` extends the same staying-in-fast-memory argument to
the *operand encoding*: the weight operand streams through HBM as int8
(1 byte/element, a 2-4x reduction on the dominant weight-side traffic),
is widened to the activation dtype in-register for the MXU dot (int8
magnitudes <= 127 are exact in bf16), and the per-channel f32 scales —
constant along k, so they commute with the contraction — are applied
once on the f32 accumulator in the last-k flush, BEFORE the epilogue
lattice, so every fused epilogue composes with quantized weights
unchanged. Dequantized weights never materialise anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pieces; interpret mode works without a TPU.
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

EPILOGUES = ("none", "bias", "bias_gelu", "bias_silu", "residual")


def _apply_epilogue(acc, e, epilogue: str):
    """Flush-phase epilogue on the f32 (or f64) accumulator tile. `e` is
    the staged epilogue operand: (1, bn) bias row or (bm, bn) residual."""
    if epilogue == "none":
        return acc
    acc = acc + e.astype(acc.dtype)       # bias broadcasts over rows
    if epilogue == "bias_gelu":
        acc = jax.nn.gelu(acc)
    elif epilogue == "bias_silu":
        acc = jax.nn.silu(acc)
    return acc


def _matmul_kernel(*refs, n_k: int, out_dtype, epilogue: str = "none"):
    if epilogue == "none":
        a_ref, b_ref, o_ref, acc_ref = refs
        e_ref = None
    else:
        a_ref, b_ref, e_ref, o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(k == n_k - 1)
    def _flush():
        acc = acc_ref[...]
        if epilogue != "none":
            acc = _apply_epilogue(acc, e_ref[...], epilogue)
        o_ref[...] = acc.astype(out_dtype)


def _matmul_q_kernel(*refs, n_k: int, out_dtype, epilogue: str = "none"):
    """Int8-weight GEMM: accumulate A @ widen(Wq) per k step; the flush
    dequantizes the f32 accumulator with the (1, bn) scale row and then
    runs the ordinary epilogue lattice."""
    if epilogue == "none":
        a_ref, b_ref, s_ref, o_ref, acc_ref = refs
        e_ref = None
    else:
        a_ref, b_ref, s_ref, e_ref, o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...].astype(a_ref.dtype),
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        acc = acc_ref[...] * s_ref[...].astype(acc_ref.dtype)
        if epilogue != "none":
            acc = _apply_epilogue(acc, e_ref[...], epilogue)
        o_ref[...] = acc.astype(out_dtype)


def _gated_matmul_kernel(a_ref, g_ref, u_ref, o_ref, accg_ref, accu_ref,
                         *, n_k: int, out_dtype):
    """Dual-GEMM SwiGLU: the A tile in VMEM feeds both weight operands;
    the flush applies the gate product without leaving fast memory."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    a = a_ref[...]
    accg_ref[...] += jnp.dot(a, g_ref[...],
                             preferred_element_type=accg_ref.dtype)
    accu_ref[...] += jnp.dot(a, u_ref[...],
                             preferred_element_type=accu_ref.dtype)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (jax.nn.silu(accg_ref[...])
                      * accu_ref[...]).astype(out_dtype)


def _clamp_block(bm: int, bn: int, bk: int, m: int, n: int, ka: int):
    """Clamp tile dims to the problem and re-validate divisibility.

    A tile larger than the (padded) problem is legitimately clamped —
    that collapses a grid dim to 1 — but a clamp must never silently
    rewrite an autotuner-served config into one that does not tile the
    problem (the old bare `assert` made that failure mode opaque).
    """
    bm_c, bn_c, bk_c = min(bm, m), min(bn, n), min(bk, ka)
    if m % bm_c or n % bn_c or ka % bk_c:
        raise ValueError(
            f"block ({bm},{bn},{bk}) clamped to ({bm_c},{bn_c},{bk_c}) "
            f"does not divide the ({m},{n},{ka}) problem; route through "
            "kernels.ops (pads operands to tile multiples) or pick tiles "
            "via core.blocking.choose_block_config")
    return bm_c, bn_c, bk_c


def _tile_params(bm: int, bn: int, acc_dtype, interpret: bool,
                 n_acc: int = 1):
    if _HAS_PLTPU:
        scratch = [pltpu.VMEM((bm, bn), acc_dtype) for _ in range(n_acc)]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY((bm, bn), acc_dtype)
                   for _ in range(n_acc)]
    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    return scratch, params


def matmul_tiled(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    block=None,
    out_dtype=None,
    interpret: bool = False,
    epilogue: str = "none",
    epilogue_operand: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """C[M,N] = epilogue(A[M,K] @ B[K,N]), real dtypes only (complex is
    decomposed in core.gemm). Shapes must be multiples of the block dims
    — ops.py pads otherwise. `block` (a core.blocking.BlockConfig, e.g.
    from the autotuner cache) overrides the bm/bn/bk defaults.

    epilogue_operand: (1, N) bias row for the bias* epilogues, (M, N)
    residual for "residual"; staged through its own BlockSpec and
    consumed in the last-k flush.
    """
    assert epilogue in EPILOGUES, epilogue
    if block is not None:
        bm, bn, bk = block.bm, block.bn, block.bk
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, (a.shape, b.shape)
    if out_dtype is None:
        out_dtype = a.dtype
    bm, bn, bk = _clamp_block(bm, bn, bk, m, n, ka)
    n_k = ka // bk
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_matmul_kernel, n_k=n_k, out_dtype=out_dtype,
                               epilogue=epilogue)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [a, b]
    if epilogue != "none":
        e = epilogue_operand
        assert e is not None, f"epilogue={epilogue} needs its operand"
        if epilogue == "residual":
            assert e.shape == (m, n), (e.shape, (m, n))
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        else:
            assert e.shape == (1, n), (e.shape, (1, n))
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(e)

    scratch, params = _tile_params(bm, bn, acc_dtype, interpret)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(*operands)


def matmul_q_tiled(
    a: jnp.ndarray,
    wq: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    block=None,
    out_dtype=None,
    interpret: bool = False,
    epilogue: str = "none",
    epilogue_operand: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """C[M,N] = epilogue((A[M,K] @ Wq[K,N]) * scale[1,N]).

    Wq is int8 (per-channel symmetric, core.precision.quantize_int8),
    scale the matching (1, N) f32 row. Same tiling contract as
    matmul_tiled; the int8 W tile halves-to-quarters the B-side DMA and
    the scale row rides its own (1, bn) BlockSpec into the flush. Note
    the TPU int8 min-tile is (32, 128) — bk from core.blocking is
    always a lane multiple, which satisfies it.
    """
    assert epilogue in EPILOGUES, epilogue
    assert wq.dtype == jnp.int8, wq.dtype
    if block is not None:
        bm, bn, bk = block.bm, block.bn, block.bk
    m, ka = a.shape
    kb, n = wq.shape
    assert ka == kb, (a.shape, wq.shape)
    assert scale.shape == (1, n), (scale.shape, n)
    if out_dtype is None:
        out_dtype = a.dtype
    bm, bn, bk = _clamp_block(bm, bn, bk, m, n, ka)
    n_k = ka // bk
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_matmul_q_kernel, n_k=n_k,
                               out_dtype=out_dtype, epilogue=epilogue)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
    ]
    operands = [a, wq, scale]
    if epilogue != "none":
        e = epilogue_operand
        assert e is not None, f"epilogue={epilogue} needs its operand"
        if epilogue == "residual":
            assert e.shape == (m, n), (e.shape, (m, n))
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        else:
            assert e.shape == (1, n), (e.shape, (1, n))
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(e)

    scratch, params = _tile_params(bm, bn, acc_dtype, interpret)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(*operands)


def gated_matmul_tiled(
    a: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    block=None,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """H[M,N] = silu(A @ Wg) * (A @ Wu) in one pass over A.

    The VMEM working set doubles on the B side (two weight tiles, two
    accumulators) — size tiles with choose_block_config(..., n_rhs=2).
    """
    m, ka = a.shape
    kg, n = w_gate.shape
    assert w_up.shape == (kg, n) and ka == kg, \
        (a.shape, w_gate.shape, w_up.shape)
    if block is not None:
        bm, bn, bk = block.bm, block.bn, block.bk
    if out_dtype is None:
        out_dtype = a.dtype
    bm, bn, bk = _clamp_block(bm, bn, bk, m, n, ka)
    n_k = ka // bk
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_gated_matmul_kernel, n_k=n_k,
                               out_dtype=out_dtype)
    scratch, params = _tile_params(bm, bn, acc_dtype, interpret, n_acc=2)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(a, w_gate, w_up)
