"""Tiled Pallas GEMM — the TPU adaptation of the paper's Listing 4.

The CUDA original stages BLOCK x BLOCK sub-matrices of A and B into
shared memory, __syncthreads(), FMAs over the block's k range, and
accumulates in a register. The TPU version:

  * the grid is (M/bm, N/bn, K/bk) with k innermost ("arbitrary"
    semantics) — the k loop of Listing 4 becomes the minor grid dim;
  * BlockSpec index maps stage (bm, bk) and (bk, bn) tiles into VMEM —
    Mosaic double-buffers the HBM->VMEM DMA, which replaces the paper's
    explicit __syncthreads() staging discipline;
  * accumulation happens in an f32 VMEM scratch tile (the register
    C_temporary of the paper, grown to a full output tile) and is cast
    to the output dtype on the last k step;
  * jnp.dot inside the kernel body maps onto the 128x128 MXU with
    preferred_element_type=f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pieces; interpret mode works without a TPU.
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def matmul_tiled(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    block=None,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N], real dtypes only (complex is decomposed
    in core.gemm). Shapes must be multiples of the block dims — ops.py
    pads otherwise. `block` (a core.blocking.BlockConfig, e.g. from the
    autotuner cache) overrides the bm/bn/bk defaults when given."""
    if block is not None:
        bm, bn, bk = block.bm, block.bn, block.bk
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, (a.shape, b.shape)
    if out_dtype is None:
        out_dtype = a.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, ka)
    assert m % bm == 0 and n % bn == 0 and ka % bk == 0, (
        f"({m},{n},{ka}) not divisible by block ({bm},{bn},{bk})")
    n_k = ka // bk
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_matmul_kernel, n_k=n_k, out_dtype=out_dtype)

    if _HAS_PLTPU:
        scratch = [pltpu.VMEM((bm, bn), acc_dtype)]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY((bm, bn), acc_dtype)]

    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(a, b)
