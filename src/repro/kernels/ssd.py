"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

This is the paper's tiling idea applied along the *time* axis: each
grid cell owns one (batch, head, chunk) tile; the decay mask, the
C·Bᵀ score matrix and the chunk-local output all live in VMEM —
exactly the tensors that dominate HBM traffic in the XLA lowering
(EXPERIMENTS §Perf, mamba2 cell).

Per cell (Q = chunk, P = head_dim, N = d_state), all f32 in VMEM:
    cs    = cumsum(a)                      (Q,)
    L     = exp(cs_i - cs_j) * [j <= i]    (Q, Q)   decay mask
    S     = (C Bᵀ) ⊙ L                     (Q, Q)   MXU matmul
    y     = S x                            (Q, P)   MXU matmul
    state = (B ⊙ exp(cs_Q - cs))ᵀ x        (N, P)   chunk state out

The inter-chunk recurrence (rank-N, tiny) and the state→output term
stay in jnp (they are O(L·N·P), not the bottleneck). ops.ssd_pallas
composes both; ref oracle = models.ssm.ssd_chunked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    q = x_ref.shape[2]
    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    a = a_ref[0, 0].astype(jnp.float32)       # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    cs = jnp.cumsum(a)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ldec = jnp.where(jj <= ii, jnp.exp(cs[:, None] - cs[None, :]), 0.0)

    scores = jax.lax.dot_general(                     # C Bᵀ: (Q, Q)
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(                          # (S ⊙ L) x: (Q, P)
        scores * ldec, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cs[-1] - cs)                  # (Q,)
    state = jax.lax.dot_general(                      # Bᵀ diag(d) x: (N, P)
        b * decay_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = state.astype(state_ref.dtype)


def ssd_intra_chunk(
    x: jnp.ndarray,    # (BH, nc, Q, P) — dt-scaled inputs
    a: jnp.ndarray,    # (BH, nc, Q)    — dt*A log decays
    b: jnp.ndarray,    # (BH, nc, Q, N)
    c: jnp.ndarray,    # (BH, nc, Q, N)
    *,
    interpret: bool = False,
):
    """Returns (y_diag (BH, nc, Q, P), states (BH, nc, N, P))."""
    bh, nc, q, p = x.shape
    n = b.shape[-1]
    grid = (bh, nc)
    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        )
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nc, n, p), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(x, a, b, c)


def ssd_pallas(
    x: jnp.ndarray,    # (B, L, H, P) — dt-scaled
    a: jnp.ndarray,    # (B, L, H)
    b_: jnp.ndarray,   # (B, L, G, N)
    c_: jnp.ndarray,   # (B, L, G, N)
    chunk: int,
    *,
    interpret: bool = False,
):
    """Drop-in for models.ssm.ssd_chunked (same contract) with the
    intra-chunk work in the Pallas kernel."""
    bsz, l, h, p = x.shape
    g, n = b_.shape[-2:]
    rep = h // g
    assert l % chunk == 0
    nc = l // chunk

    # (B, L, H, *) -> (B*H, nc, Q, *)
    xk = x.transpose(0, 2, 1, 3).reshape(bsz * h, nc, chunk, p)
    ak = a.transpose(0, 2, 1).reshape(bsz * h, nc, chunk)
    bk = jnp.repeat(b_, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, nc, chunk, n)
    ck = jnp.repeat(c_, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, nc, chunk, n)

    y_diag, states = ssd_intra_chunk(xk, ak, bk, ck, interpret=interpret)

    # inter-chunk recurrence in jnp (tiny rank-N state)
    ac = ak.reshape(bsz, h, nc, chunk)
    a_cum = jnp.cumsum(ac, axis=-1)
    chunk_decay = jnp.exp(a_cum[..., -1])                  # (B,H,nc)
    states = states.reshape(bsz, h, nc, n, p)

    def step(s, inp):
        st, dec = inp
        return s * dec[..., None, None] + st, s
    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    s_final, prev = jax.lax.scan(
        step, s0, (states.transpose(2, 0, 1, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 2, 0, 3, 4)                   # (B,H,nc,N,P)

    state_decay = jnp.exp(a_cum)                           # (B,H,nc,Q)
    ck5 = ck.reshape(bsz, h, nc, chunk, n)
    y_off = jnp.einsum("bhcqn,bhcnp,bhcq->bhcqp", ck5, prev, state_decay)
    y = y_diag.reshape(bsz, h, nc, chunk, p) + y_off
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)      # (B,L,H,P)
    # final state layout to match ssd_chunked: (B, H, P, N)
    return y, s_final.swapaxes(-1, -2)
