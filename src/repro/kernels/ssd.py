"""SSD (Mamba-2 state-space duality) kernels: jnp chunked reference +
Pallas TPU intra-chunk kernel.

The SSD dual form is the paper's tiling idea applied along the *time*
axis: the sequence is chunked, intra-chunk terms are dense
(decay-masked) matmuls and inter-chunk terms are a rank-N state
recurrence. Chunking is mathematically exact — the chunk size is a
pure performance knob, which is what makes the op autotunable (the
execution chunk swept by `tuning.tune_ssd` can differ from the model's
configured chunk; only float rounding changes).

Two implementations share one contract
    (x (B,L,H,P), a (B,L,H), b (B,L,G,N), c (B,L,G,N), chunk,
     init_state (B,H,P,N) or None) -> (y (B,L,H,P) in x.dtype,
                                       final_state (B,H,P,N) f32)
and carry the inter-chunk state in f32 regardless of input dtype
(cast at the boundary), so bf16 runs agree across backends:

* `ssd_chunked` — the jnp composition (the xla backend and the VJP's
  unfused target). Everything is computed in f32.
* `ssd_pallas`  — intra-chunk work in the Pallas kernel below; the
  decay mask, the C·Bᵀ score matrix and the chunk-local output live in
  VMEM — exactly the tensors that dominate HBM traffic in the XLA
  lowering (EXPERIMENTS §SSD traffic accounting).

Per grid cell (Q = chunk, BP = head_dim tile, N = d_state), f32:
    cs    = cumsum(a)                      (Q,)
    L     = exp((cs_i - cs_j)[j <= i])     (Q, Q)   decay mask
    S     = (C Bᵀ) ⊙ L                     (Q, Q)   MXU matmul
    y     = S x                            (Q, BP)  MXU matmul
    state = (B ⊙ exp(cs_Q - cs))ᵀ x        (N, BP)  chunk state out

The inter-chunk recurrence (rank-N, tiny) and the state→output term
stay in jnp (they are O(L·N·P), not the bottleneck). The log-space
decay argument is masked *before* the exp (as `_segsum` does): the
upper triangle of cs_i - cs_j is positive and overflows to inf for
strong decays, which would NaN gradients through a post-exp `where`.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) -> (..., Q, Q) with S[i,j] = sum_{j<m<=i} a[..., m],
    -inf above the diagonal (log-space decay mask)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    return jnp.where(jj <= ii, s, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, L, H, P) — already dt-scaled
    a: jnp.ndarray,      # (B, L, H)    — dt * A (negative log-decay)
    b_: jnp.ndarray,     # (B, L, G, N)
    c_: jnp.ndarray,     # (B, L, G, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
):
    """Chunked jnp reference. Returns (y in x.dtype, final_state f32);
    all interior math — including the carried inter-chunk state — is
    f32, so bf16 inputs follow the same accumulation discipline as the
    Pallas kernel (f64 inputs keep f64 accumulation, like matmul_ref)."""
    bsz, l, h, p = x.shape
    g, n = b_.shape[-2:]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    acc = jnp.float64 if x.dtype == jnp.float64 else jnp.float32

    xc = x.astype(acc).reshape(bsz, nc, chunk, h, p)
    ac = a.astype(acc).reshape(bsz, nc, chunk, h) \
        .transpose(0, 1, 3, 2)                                # (B,nc,H,Q)
    bc = jnp.repeat(
        b_.astype(acc).reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(
        c_.astype(acc).reshape(bsz, nc, chunk, g, n), rep, axis=3)

    # 1. intra-chunk (dense blocked matmul with decay mask)
    ldec = jnp.exp(_segsum(ac))                               # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcqhn,bcshn->bchqs", cc, bc)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", cb * ldec, xc)

    # 2. per-chunk states
    a_cum = jnp.cumsum(ac, axis=-1)                           # (B,nc,H,Q)
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)           # (B,nc,H,Q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        bc, decay_to_end, xc)                 # (B,nc,H,P,N)

    # 3. inter-chunk recurrence (f32 state, seeded by init_state)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (B,nc,H)
    s0 = (jnp.zeros((bsz, h, p, n), acc)
          if init_state is None else init_state.astype(acc))

    def step(s, inp):
        st, dec = inp
        return s * dec[..., None, None] + st, s               # emit state *before*

    (s_final, prev_states) = jax.lax.scan(
        step, s0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                  # (B,nc,H,P,N)

    # 4. state -> output within each chunk
    state_decay = jnp.exp(a_cum)                              # (B,nc,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(bsz, l, h, p).astype(x.dtype)
    return y, s_final


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    q = x_ref.shape[2]
    x = x_ref[0, 0].astype(jnp.float32)       # (Q, BP)
    a = a_ref[0, 0].astype(jnp.float32)       # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    cs = jnp.cumsum(a)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask the log-space argument BEFORE exp: the upper triangle of
    # cs_i - cs_j is positive and overflows for strong decays, and a
    # post-exp where() would propagate NaN through the VJP.
    ldec = jnp.exp(jnp.where(jj <= ii, cs[:, None] - cs[None, :], -jnp.inf))

    scores = jax.lax.dot_general(                     # C Bᵀ: (Q, Q)
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(                          # (S ⊙ L) x: (Q, BP)
        scores * ldec, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cs[-1] - cs)                  # (Q,)
    state = jax.lax.dot_general(                      # Bᵀ diag(d) x: (N, BP)
        b * decay_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = state.astype(state_ref.dtype)


def ssd_intra_chunk(
    x: jnp.ndarray,    # (BH, nc, Q, P) — dt-scaled inputs
    a: jnp.ndarray,    # (BH, nc, Q)    — dt*A log decays
    b: jnp.ndarray,    # (BH, nc, Q, N)
    c: jnp.ndarray,    # (BH, nc, Q, N)
    *,
    block_p: Optional[int] = None,
    interpret: bool = False,
):
    """Returns (y_diag (BH, nc, Q, P), states (BH, nc, N, P)).

    `block_p` tiles the head dim: each (bh, chunk, p-tile) grid cell
    recomputes the (Q, Q) decay mask and score matrix for its slice —
    smaller working set per cell at the price of redundant score
    compute; the autotuner decides (tuning/space.py::ssd_candidates).
    """
    bh, nc, q, p = x.shape
    n = b.shape[-1]
    bp = block_p or p
    if p % bp:
        bp = p
    grid = (bh, nc, p // bp)
    params = {}
    if _HAS_PLTPU and not interpret:
        params["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        )
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, bp), lambda i, j, k: (i, j, 0, k)),
            pl.BlockSpec((1, 1, q), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, bp), lambda i, j, k: (i, j, 0, k)),
            pl.BlockSpec((1, 1, n, bp), lambda i, j, k: (i, j, 0, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nc, n, p), jnp.float32),
        ],
        interpret=interpret,
        **params,
    )(x, a, b, c)


def ssd_pallas(
    x: jnp.ndarray,    # (B, L, H, P) — dt-scaled
    a: jnp.ndarray,    # (B, L, H)
    b_: jnp.ndarray,   # (B, L, G, N)
    c_: jnp.ndarray,   # (B, L, G, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
    *,
    block_p: Optional[int] = None,
    interpret: bool = False,
):
    """Drop-in for `ssd_chunked` (same contract, incl. `init_state`
    seeding the inter-chunk scan) with the intra-chunk work in the
    Pallas kernel. `chunk` here is the *execution* chunk — any divisor
    of L computes the same function."""
    bsz, l, h, p = x.shape
    g, n = b_.shape[-2:]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    # (B, L, H, *) -> (B*H, nc, Q, *)
    xk = x.transpose(0, 2, 1, 3).reshape(bsz * h, nc, chunk, p)
    ak = a.transpose(0, 2, 1).reshape(bsz * h, nc, chunk)
    bk = jnp.repeat(b_, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, nc, chunk, n)
    ck = jnp.repeat(c_, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * h, nc, chunk, n)

    y_diag, states = ssd_intra_chunk(
        xk, ak, bk, ck, block_p=block_p, interpret=interpret)

    # inter-chunk recurrence in jnp (tiny rank-N state, carried f32)
    ac = ak.astype(jnp.float32).reshape(bsz, h, nc, chunk)
    a_cum = jnp.cumsum(ac, axis=-1)
    chunk_decay = jnp.exp(a_cum[..., -1])                  # (B,H,nc)
    states = states.reshape(bsz, h, nc, n, p)

    def step(s, inp):
        st, dec = inp
        return s * dec[..., None, None] + st, s
    # internal state layout is (N, P); the contract's is (B, H, P, N)
    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32)
          if init_state is None
          else init_state.swapaxes(-1, -2).astype(jnp.float32))
    s_final, prev = jax.lax.scan(
        step, s0, (states.transpose(2, 0, 1, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 2, 0, 3, 4)                   # (B,H,nc,N,P)

    state_decay = jnp.exp(a_cum)                           # (B,H,nc,Q)
    ck5 = ck.astype(jnp.float32).reshape(bsz, h, nc, chunk, n)
    y_off = jnp.einsum("bhcqn,bhcnp,bhcq->bhcqp", ck5, prev, state_decay)
    y = y_diag.reshape(bsz, h, nc, chunk, p) + y_off
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)      # (B,L,H,P)
    # final state layout to match ssd_chunked: (B, H, P, N)
    return y.astype(x.dtype), s_final.swapaxes(-1, -2)
