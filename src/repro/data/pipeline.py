"""Data pipeline: deterministic, shard-aware token streams.

Two sources:
  * SyntheticLM — seeded Zipf-ish token stream; (step, shard) fully
    determines contents, so restarts/elastic re-shards reproduce the
    exact batch sequence (a fault-tolerance requirement, not a toy).
  * MemmapCorpus — flat binary token file, strided by (step, shard).

Both yield {"tokens": (B, S), "labels": (B, S)} with labels = tokens
shifted left (next-token prediction), last label masked.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int                 # per-host batch
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, n_shards]))
        # Zipf-distributed ids clipped to vocab — realistic token skew.
        toks = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len + 1))
        toks = (toks - 1) % self.vocab
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32).copy()
        labels[:, -1] = -1
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class MemmapCorpus:
    path: str
    vocab: int
    seq_len: int
    batch: int
    dtype: str = "uint16"

    def _data(self):
        return np.memmap(self.path, dtype=self.dtype, mode="r")

    def n_batches(self) -> int:
        n_tok = self._data().shape[0]
        return n_tok // (self.batch * (self.seq_len + 1))

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        data = self._data()
        span = self.batch * (self.seq_len + 1)
        n = data.shape[0] // span
        idx = (step * n_shards + shard) % max(n, 1)
        chunk = np.asarray(data[idx * span:(idx + 1) * span], dtype=np.int64)
        chunk = (chunk % self.vocab).reshape(self.batch, self.seq_len + 1)
        tokens = chunk[:, :-1].astype(np.int32)
        labels = chunk[:, 1:].astype(np.int32).copy()
        labels[:, -1] = -1
        return {"tokens": tokens, "labels": labels}


def write_corpus(path: str, tokens: np.ndarray, dtype: str = "uint16"):
    np.asarray(tokens, dtype=dtype).tofile(path)
