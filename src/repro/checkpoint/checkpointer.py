"""Sharded, atomic, async checkpointing with elastic restore.

Layout:   <dir>/step_<N>/
             manifest.json      tree structure, shapes, dtypes, step,
                                logical sharding spec (axis names only)
             arr_<i>.npy        one file per leaf

Properties the fault-tolerance layer relies on:
  * atomic: written to step_<N>.tmp then os.rename'd — a crash mid-save
    never corrupts the latest checkpoint;
  * async: `save(..., blocking=False)` hands the host copy to a writer
    thread; training continues (the copy is snapshotted first);
  * elastic: arrays are stored *unsharded* with their logical
    PartitionSpec recorded, so restore() can re-lay them onto a mesh of
    a different extent (data-parallel width change, pod loss).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------- save
    def save(self, step: int, tree: Any, *, spec: Any = None,
             blocking: bool = True) -> None:
        self.wait()
        # Snapshot to host memory before returning control. Non-native
        # dtypes (bfloat16) are stored as raw uint16 views — numpy can
        # neither save nor cast ml_dtypes reliably.
        leaves, treedef = _flatten(tree)
        host, raw_views = [], []
        for x in leaves:
            a = np.asarray(x)
            if a.dtype.str in ("<V2", "|V2") or a.dtype.name == "bfloat16":
                host.append(a.view(np.uint16))
                raw_views.append("bfloat16")
            else:
                host.append(a)
                raw_views.append(None)
        treedef_str = str(treedef)
        spec_leaves = None
        if spec is not None:
            spec_leaves = [str(s) for s in jax.tree.leaves(
                spec, is_leaf=lambda x: x is None) ]

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "treedef": treedef_str,
                "n_leaves": len(host),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
                "raw_views": raw_views,
                "spec": spec_leaves,
            }
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -------------------------------------------------- restore
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of `like`. If `shardings` (a pytree
        of jax.sharding.Sharding matching `like`) is given, leaves are
        device_put with it — this is the elastic-remesh path."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), (
            manifest["n_leaves"], len(leaves_like))
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        raw_views = manifest.get("raw_views") or [None] * len(leaves_like)
        for i, (ref, shd) in enumerate(zip(leaves_like, shard_leaves)):
            a = np.load(os.path.join(path, f"arr_{i}.npy"))
            if raw_views[i] == "bfloat16":
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            assert list(a.shape) == list(ref.shape), (i, a.shape, ref.shape)
            if a.dtype != ref.dtype:
                a = np.asarray(jax.numpy.asarray(a).astype(ref.dtype))
            out.append(jax.device_put(a, shd) if shd is not None
                       else jax.numpy.asarray(a))
        return jax.tree.unflatten(treedef, out)
