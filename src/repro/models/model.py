"""Top-level model API, uniform across all 10 assigned architectures.

    params = init_params(cfg, key)
    loss, metrics = loss_fn(cfg, params, batch)            # train
    cache = init_cache(cfg, batch_size, max_len)
    logits, cache = prefill(cfg, params, batch, cache)     # inference
    logits, cache = decode_step(cfg, params, token, pos, cache)

Batch keys by family:
  decoder/moe : tokens, labels
  vlm         : tokens, patch_embeds (aligned, zeros at text pos),
                positions (B,S,3 M-RoPE), labels
  ssm/hybrid  : tokens, labels
  encdec      : enc_frames (stub conv-frontend output), tokens, labels
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import precision as _prec
from repro.distributed.context import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                              dtype=dtype),
        "final_norm": T._norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], cfg.d_model, cfg.padded_vocab,
                                    dtype=dtype)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = T.stack_init(ks[2], cfg)
    elif fam == "ssm":
        p["layers"] = T.ssm_stack_init(ks[2], cfg)
    elif fam == "hybrid":
        p["hybrid"] = T.hybrid_init(ks[2], cfg)
    elif fam == "encdec":
        enc_cfg = dataclasses.replace(cfg, n_layers=cfg.n_enc_layers)
        p["enc_layers"] = T.stack_init(ks[3], enc_cfg)
        p["enc_final_norm"] = T._norm_init(cfg)
        p["dec_layers"] = T.stack_init(ks[4], cfg, cross=True)
    else:
        raise ValueError(fam)
    return p


#: Param subtrees never quantized: embeddings are gathered, not
#: matmul'd (and tied lm_heads attend through them), and the MoE router
#: is a negligible-byte f32 GEMM whose argmax decides expert routing —
#: a quantization-grid flip there reroutes whole tokens.
QUANT_EXCLUDE = ("embed", "router")


def quantize_params(params, *, spec=None, exclude=QUANT_EXCLUDE):
    """Walk a param tree and quantize every dense-layer weight dict
    ({"w": 2D/3D float, "b"?} from layers.dense_init — scanned stacks
    carry a leading layer dim) to int8 via layers.dense_quantize.
    dense_apply/gated_apply then route those layers through
    core.gemm.dense_q; the serving engine calls this once at
    construction when its pinned policy has quant="int8". MoE expert
    banks (raw 3D arrays, not dicts) and the `exclude` subtrees pass
    through unchanged."""
    spec = spec or _prec.QuantSpec()

    def rec(node, name):
        if isinstance(node, dict):
            w = node.get("w")
            if (w is not None and getattr(w, "ndim", 0) in (2, 3)
                    and name not in exclude
                    and jnp.issubdtype(w.dtype, jnp.floating)):
                return L.dense_quantize(node, spec)
            return {k: rec(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, name) for v in node)
        return node

    return rec(params, "")


# ----------------------------------------------------------------------
# forward (full-sequence) per family
# ----------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], batch["tokens"], dtype=dtype)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # Vision stub: precomputed patch embeddings arrive aligned with
        # the token grid (zeros at text positions) and are added in.
        x = x + batch["patch_embeds"].astype(dtype)
    return constrain(x, "dp", None, None)


def _logits(cfg, params, x):
    x = T._norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.embed_attend(params["embed"], x)
    else:
        logits = L.dense_apply(params["lm_head"], x, out_dtype=jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        # Megatron-style vocab padding: mask pad classes out of softmax.
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return constrain(logits, "dp", None, "tp")


def _run_encoder(cfg, params, frames):
    enc_cfg = dataclasses.replace(cfg, n_layers=cfg.n_enc_layers)
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoid_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x, _, _ = T.stack_apply(params["enc_layers"], x, enc_cfg, causal=False)
    return T._norm_apply(cfg, params["enc_final_norm"], x)


def forward(cfg, params, batch) -> tuple[jnp.ndarray, dict]:
    """Full-sequence logits (training / evaluation). Returns (logits, aux)."""
    fam = cfg.family
    aux: dict = {}
    if fam in ("dense", "moe", "vlm"):
        x = _embed_inputs(cfg, params, batch)
        x, _, aux = T.stack_apply(params["layers"], x, cfg,
                                  positions=batch.get("positions"))
    elif fam == "ssm":
        x = _embed_inputs(cfg, params, batch)
        x, _ = T.ssm_stack_apply(params["layers"], x, cfg)
    elif fam == "hybrid":
        x = _embed_inputs(cfg, params, batch)
        x, _, _ = T.hybrid_apply(params["hybrid"], x, cfg, emb0=x)
    elif fam == "encdec":
        enc_out = _run_encoder(cfg, params, batch["enc_frames"])
        x = _embed_inputs(cfg, params, batch)
        x = x + L.sinusoid_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
        x, _, aux = T.stack_apply(params["dec_layers"], x, cfg,
                                  enc_out=enc_out)
    else:
        raise ValueError(fam)
    return _logits(cfg, params, x), aux


def loss_fn(cfg, params, batch):
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    valid = labels >= 0
    labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll * valid) / denom
    metrics = {"ce_loss": loss, "tokens": denom}
    for k, v in aux.items():
        metrics[k] = v
        if k.endswith("_loss"):
            loss = loss + v
    metrics["loss"] = loss
    return loss, metrics


# ----------------------------------------------------------------------
# KV / state caches
# ----------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim
    fam = cfg.family

    def kv(layers, length, heads):
        shape = (layers, batch, length, heads, dh)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    if fam in ("dense", "moe", "vlm"):
        return kv(cfg.n_layers, max_len, cfg.n_kv_heads)
    if fam == "ssm":
        st = S.mamba_init_state(cfg, batch, dtype=dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), st)
    if fam == "hybrid":
        n_seg = cfg.n_layers // cfg.attn_every
        st = S.mamba_init_state(cfg, batch, dtype=dtype)
        mamba = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (n_seg, cfg.attn_every) + a.shape).copy(), st)
        return {"mamba": mamba, "attn": kv(n_seg, max_len, cfg.n_kv_heads)}
    if fam == "encdec":
        return {"self": kv(cfg.n_layers, max_len, cfg.n_kv_heads),
                "cross": kv(cfg.n_layers, enc_len or cfg.enc_ctx,
                            cfg.n_kv_heads)}
    raise ValueError(fam)


def init_paged_cache(cfg, n_pages: int, page_size: int, max_slots: int,
                     pages_per_slot: int, *, quant_kv: str = "off"):
    """Page-pool KV cache for continuous-batching decode (see
    serving.kv_pool for the host-side bookkeeping). Layout:

        {"pages": {"k", "v": (L, n_pages, page_size, Hkv, Dh)
                   [, "ks", "vs": (L, n_pages, Hkv, page_size) f32]},
         "table": (max_slots, pages_per_slot) int32, -1 = unmapped}

    quant_kv="int8" stores int8 pages plus per-(position, head) f32
    scale planes; the decode kernel dequantizes on its f32 accumulator.
    Attention-cache families only — ssm/hybrid state is recurrent, not
    token-addressed, so pages don't apply (and encdec's cross cache is
    read-only whole-sequence)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged KV cache supports dense/moe/vlm, not {cfg.family!r}")
    dh = cfg.resolved_head_dim
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, dh)
    if quant_kv == "int8":
        pages = {"k": jnp.zeros(shape, jnp.int8),
                 "v": jnp.zeros(shape, jnp.int8),
                 "ks": jnp.zeros(shape[:2] + (cfg.n_kv_heads, page_size),
                                 jnp.float32),
                 "vs": jnp.zeros(shape[:2] + (cfg.n_kv_heads, page_size),
                                 jnp.float32)}
    elif quant_kv == "off":
        dtype = jnp.dtype(cfg.dtype)
        pages = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    else:
        raise ValueError(f"unknown quant_kv {quant_kv!r}")
    return {"pages": pages,
            "table": jnp.full((max_slots, pages_per_slot), -1, jnp.int32)}


# ----------------------------------------------------------------------
# prefill / decode
# ----------------------------------------------------------------------

def prefill(cfg, params, batch, cache, pos: int = 0):
    """Run the prompt through the model, filling `cache`. Returns
    (last-position logits, cache)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        x = _embed_inputs(cfg, params, batch)
        x, cache, _ = T.stack_apply(params["layers"], x, cfg,
                                    positions=batch.get("positions"),
                                    caches=cache, cache_pos=pos)
    elif fam == "ssm":
        x = _embed_inputs(cfg, params, batch)
        x, cache = T.ssm_stack_apply(params["layers"], x, cfg, states=cache)
    elif fam == "hybrid":
        x = _embed_inputs(cfg, params, batch)
        x, attn_c, mamba_c = T.hybrid_apply(
            params["hybrid"], x, cfg, emb0=x,
            attn_caches=cache["attn"], cache_pos=pos,
            mamba_states=cache["mamba"])
        cache = {"mamba": mamba_c, "attn": attn_c}
    elif fam == "encdec":
        enc_out = _run_encoder(cfg, params, batch["enc_frames"])
        cross = jax.vmap(
            lambda lp: A.project_cross_kv(lp["cross_attn"], enc_out, cfg)
        )(params["dec_layers"])
        cross = {"k": cross[0], "v": cross[1]}
        x = _embed_inputs(cfg, params, batch)
        x = x + L.sinusoid_positions(
            x.shape[1], cfg.d_model, pos)[None].astype(x.dtype)
        x, self_c, _ = T.stack_apply(
            params["dec_layers"], x, cfg, caches=cache["self"],
            cache_pos=pos, cross_caches=cross)
        cache = {"self": self_c, "cross": cross}
    else:
        raise ValueError(fam)
    return _logits(cfg, params, x[:, -1:]), cache


def decode_step(cfg, params, token, pos, cache):
    """One-token step. token: (B, 1) int32; pos: scalar int32, or a (B,)
    per-slot position vector (continuous batching: each batch row is an
    independent request at its own depth; pos < 0 marks an inactive slot
    whose cache is left untouched and whose logits are garbage)."""
    fam = cfg.family
    batch = {"tokens": token}
    if fam in ("dense", "moe", "vlm"):
        if fam == "vlm":
            # text token in decode: t = h = w = pos (M-RoPE degenerate)
            b = token.shape[0]
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32).reshape((-1, 1, 1)), (b, 1, 3)) \
                if cfg.mrope_sections else None
        x = _embed_inputs(cfg, params, batch)
        if isinstance(cache, dict) and "pages" in cache:
            # Paged cache (init_paged_cache): scan the page pools as
            # layer xs, close over the layer-less table.
            x, pages, _ = T.stack_apply(params["layers"], x, cfg,
                                        positions=batch.get("positions"),
                                        caches=cache["pages"], cache_pos=pos,
                                        kv_table=cache["table"])
            cache = {"pages": pages, "table": cache["table"]}
        else:
            x, cache, _ = T.stack_apply(params["layers"], x, cfg,
                                        positions=batch.get("positions"),
                                        caches=cache, cache_pos=pos)
    elif fam == "ssm":
        x = _embed_inputs(cfg, params, batch)
        x, cache = T.ssm_stack_apply(params["layers"], x, cfg,
                                     states=cache, decode=True)
    elif fam == "hybrid":
        x = _embed_inputs(cfg, params, batch)
        x, attn_c, mamba_c = T.hybrid_apply(
            params["hybrid"], x, cfg, emb0=x,
            attn_caches=cache["attn"], cache_pos=pos,
            mamba_states=cache["mamba"], decode=True)
        cache = {"mamba": mamba_c, "attn": attn_c}
    elif fam == "encdec":
        x = _embed_inputs(cfg, params, batch)
        # offset the sinusoid by pos dynamically (scalar or per-slot)
        pe = _sinusoid_at(cfg.d_model, pos).reshape((-1, 1, cfg.d_model))
        x = x + pe.astype(x.dtype)
        x, self_c, _ = T.stack_apply(
            params["dec_layers"], x, cfg, caches=cache["self"],
            cache_pos=pos, cross_caches=cache["cross"])
        cache = {"self": self_c, "cross": cache["cross"]}
    else:
        raise ValueError(fam)
    return _logits(cfg, params, x), cache


def verify_step(cfg, params, tokens, pos, n_tok, cache):
    """Speculative-verification step: one batched multi-token forward.

    tokens: (B, T) int32 — per slot, the pending token followed by the
    draft proposals; pos: (B,) per-slot write position of tokens[:, 0]
    (pos < 0 = inactive slot); n_tok: (B,) count of valid rows per slot
    (rows past n_tok neither write KV nor attend — slots nearing their
    generation budget propose fewer than T-1 drafts).

    Returns (logits (B, T, V), cache). logits[:, j] is the target
    distribution for stream position pos + j + 1, so row j-1 scores
    draft token j and row n_tok-1 supplies the bonus token. This is a
    prefill-shaped call (all T positions in one GEMM pass over the
    tuned kernel stack), NOT T decode steps — the whole point of
    speculative decoding under the paper's batching thesis.
    """
    fam = cfg.family
    if fam not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"speculative verification supports dense/moe/vlm, not {fam!r}")
    pos = jnp.asarray(pos, jnp.int32)
    n_tok = jnp.asarray(n_tok, jnp.int32)
    batch = {"tokens": tokens}
    if fam == "vlm" and cfg.mrope_sections:
        b, t = tokens.shape
        wpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
        batch["positions"] = jnp.broadcast_to(wpos[..., None], (b, t, 3))
    x = _embed_inputs(cfg, params, batch)
    if isinstance(cache, dict) and "pages" in cache:
        x, pages, _ = T.stack_apply(params["layers"], x, cfg,
                                    positions=batch.get("positions"),
                                    caches=cache["pages"], cache_pos=pos,
                                    kv_table=cache["table"], n_valid=n_tok)
        cache = {"pages": pages, "table": cache["table"]}
    else:
        x, cache, _ = T.stack_apply(params["layers"], x, cfg,
                                    positions=batch.get("positions"),
                                    caches=cache, cache_pos=pos,
                                    n_valid=n_tok)
    return _logits(cfg, params, x), cache


def _sinusoid_at(d: int, pos) -> jnp.ndarray:
    """Sinusoid row(s) at `pos` (scalar -> (d,), vector (B,) -> (B, d))."""
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, d, 2) / d)
    p = jnp.asarray(pos, jnp.float32)
    ang = p[..., None] * div
    pe = jnp.zeros(p.shape + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
