"""Attention layers: GQA/MQA, qk-norm, QKV bias, RoPE/M-RoPE, sliding
window, cross-attention, KV-cache decode.

Two execution paths, both memory-hierarchy-aware (the paper's tiling
insight):
  * XLA path — online-softmax over KV chunks via lax.scan; the S matrix
    never exceeds (q, chunk). Differentiable; what the dry-run lowers.
  * Pallas path — kernels/flash_attention.py, the TPU target; swapped
    in through kernels.ops (validated in interpret mode on CPU).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import policy as _pol
from repro.core import precision as _prec
from repro.core.policy import Policy
from repro.distributed.context import constrain, current_mesh
from repro.kernels import ops as kops
from repro.models import layers as L


def _constrain_bthd(x, cfg):
    """Shard a (B, T, H, D) attention tensor: heads over "model" when
    divisible, else (opt-in) the sequence dim — context parallelism for
    head counts like 40 that don't divide the 16-wide model axis."""
    mesh = current_mesh()
    if mesh is None:
        return x
    tp = mesh.shape["model"]
    fallback = None if cfg.constrain_mode == "replicate" else "free"
    if x.shape[2] % tp == 0:
        return constrain(x, "dp", None, "tp", None)
    if cfg.shard_attn_seq and x.shape[1] % tp == 0:
        return constrain(x, "dp", "tp", fallback, None)
    return constrain(x, "dp", None, fallback, None)


# ----------------------------------------------------------------------
# Chunked online-softmax attention (pure jnp, differentiable)
# ----------------------------------------------------------------------

def chunked_attention(
    q: jnp.ndarray,               # [B, Tq, H, D]
    k: jnp.ndarray,               # [B, Tk, Hkv, D]
    v: jnp.ndarray,               # [B, Tk, Hkv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 2048,
    q_offset=0,                   # int / traced scalar / (B,) vector (decode)
    kv_len=None,                  # valid-length mask: scalar or (B,) vector
    io_dtype=jnp.float32,         # bf16 = flash-kernel numerics (§Perf)
) -> jnp.ndarray:
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    g = h // hkv
    scale = d ** -0.5
    chunk = min(chunk, tk)
    assert tk % chunk == 0, (tk, chunk)
    n_chunks = tk // chunk

    qf = (q.astype(io_dtype) * jnp.asarray(scale, io_dtype)) \
        .reshape(b, tq, hkv, g, d)
    kc = k.astype(io_dtype).reshape(b, n_chunks, chunk, hkv, d)
    vc = v.astype(io_dtype).reshape(b, n_chunks, chunk, hkv, d)

    # Position grids broadcast to (Bm, Tq, chunk) where Bm is 1 for the
    # uniform (scalar-offset) case and B for per-slot vectors. A slot
    # with kv_len == 0 (inactive, pos < 0) masks every key; its output
    # is finite garbage the caller discards.
    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(tq)[None, :, None] + \
        (q_off[:, None, None] if q_off.ndim else q_off)     # [Bm, Tq, 1]
    kl = None
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim else kl

    def step(carry, inp):
        m, l, acc = carry
        kci, vci, c_idx = inp
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kci,
                       preferred_element_type=jnp.float32)
        k_pos = c_idx * chunk + jnp.arange(chunk)[None, None, :]
        mask = jnp.ones((1, tq, chunk), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        if kl is not None:
            mask &= k_pos < kl
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        s_max = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, s_max)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, tq, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, tq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, tq, hkv, g, d), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = step((m0, l0, a0),
                              (kc[:, 0], vc[:, 0], jnp.int32(0)))
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             jnp.arange(n_chunks, dtype=jnp.int32)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, tq, h, d)
    return out.astype(q.dtype)


def _resolve_attn_policy(policy, backend) -> Policy:
    """Attention follows the ambient execution policy like every other
    chokepoint: the flash kernel has a registered backward
    (flash_attention_bwd) and a decode kernel (flash_decode), so the
    historical fwd-only XLA-default carve-out is gone. Code that relied
    on the old opt-in contract — an ambient pallas scope silently
    getting the chunked XLA path here — gets a one-time deprecation
    notice the first time the new resolution changes its routing."""
    if policy is None and backend is None:
        pol = _pol.current_policy()
        if pol.backend != "xla":
            _pol.warn_deprecated(
                "attn_xla_default_carveout",
                "attention now follows the ambient execution policy: the "
                "flash kernel gained a fused backward and a decode kernel, "
                "so the old backward-unsupported XLA-default carve-out is "
                "removed — pass policy=Policy() explicitly to keep the "
                "chunked XLA path under a non-xla scope")
        return pol
    return _pol.resolve(policy, backend)


_XLA_POLICY = Policy()


def _route_dtype(pol: Policy, dtype) -> Policy:
    """The flash kernels accumulate in f32 by construction, so f64
    requests reroute to the XLA chunked path, which honours the wider
    dtype (mirrors core.gemm._route_dtype, but unconditional: interpret
    mode would silently downcast too)."""
    if jnp.dtype(dtype) == jnp.float64 and pol.backend != "xla":
        return pol.replace(backend="xla")
    return pol


def _flash_shapes_ok(tq: int, tk: int) -> bool:
    """The kernels require block sizes to divide the sequence lengths
    after clamping (flash_attention asserts it); ragged shapes fall
    back to the chunked path."""
    return tq % min(256, tq) == 0 and tk % min(512, tk) == 0


# The fused custom-VJP chokepoint. causal/window/policy ride as nondiff
# arguments (hashable — the core.gemm pattern), so the backward op runs
# under the same execution policy as the forward.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_fused(q, k, v, causal, window, pol):
    o, _ = kops.flash_attention_fwd(
        q, k, v, causal=causal, window=window, policy=pol)
    return o


def _attention_fused_fwd(q, k, v, causal, window, pol):
    o, lse = kops.flash_attention_fwd(
        q, k, v, causal=causal, window=window, policy=pol)
    return o, (q, k, v, o, lse)


def _attention_fused_bwd(causal, window, pol, res, do):
    q, k, v, o, lse = res
    return kops.flash_attention_bwd(
        q, k, v, o, do, lse, causal=causal, window=window, policy=pol)


_attention_fused.defvjp(_attention_fused_fwd, _attention_fused_bwd)


def attention(q, k, v, *, causal, window, chunk, q_offset=0, kv_len=None,
              policy: Policy | None = None, backend: str | None = None,
              io_dtype=jnp.float32, decode: bool = False):
    """The attention chokepoint (né `attend`). Routing under the
    resolved policy:

      * pallas + decode step (t == 1, kv_len = pos + 1): the
        flash_decode kernel — K/V stream only over each slot's valid
        cache prefix.
      * pallas + full-kv (kv_len None, block-divisible shapes, zero
        q_offset): the fused custom-VJP path — flash forward saving the
        per-row logsumexp, flash_attention_bwd for gradients (replacing
        differentiate-through-chunked).
      * everything else (xla policy, f64, ragged shapes, masked
        prefill): the chunked online-softmax path, differentiable by
        construction.

    The XLA path is wrapped in a named_scope so the roofline analyzer
    can identify attention-interior traffic — on the TPU target this
    whole region is the Pallas flash kernel (same math, validated in
    interpret mode) whose intermediates never touch HBM. §Perf models
    that substitution from the tag.
    """
    pol = _route_dtype(_resolve_attn_policy(policy, backend), q.dtype)
    if pol.backend == "pallas":
        if decode and q.shape[1] == 1 and k.shape[1] % min(512, k.shape[1]) == 0:
            # kv_len = q_offset + 1 by the decode contract: the kernel's
            # per-row prefix mask IS causal masking at depth q_offset.
            return kops.flash_decode(
                q, k, v, pos=q_offset, window=window, policy=pol)
        if kv_len is None and _flash_shapes_ok(q.shape[1], k.shape[1]) \
                and isinstance(q_offset, int) and q_offset == 0:
            return _attention_fused(q, k, v, causal, window, pol)
    elif pol.backend != "xla" and kv_len is None:
        # naive etc.: the forward-only op (registry raises for backends
        # with no flash impl, listing the registered ones)
        return kops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            policy=pol)
    with jax.named_scope("flashsite"):
        return chunked_attention(
            q, k, v, causal=causal, window=window, chunk=chunk,
            q_offset=q_offset, kv_len=kv_len, io_dtype=io_dtype)


#: Backwards-compatible alias — attn_apply and external callers used
#: the old name; same function, same signature.
attend = attention


# ----------------------------------------------------------------------
# Attention layer (self + cross)
# ----------------------------------------------------------------------

def attn_init(key, cfg, *, d_model=None, cross: bool = False):
    d = d_model or cfg.d_model
    dh = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, h * dh, dtype=dtype, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], d, hkv * dh, dtype=dtype, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], d, hkv * dh, dtype=dtype, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], h * dh, d, dtype=dtype,
                           scale=(h * dh) ** -0.5 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh, dtype=dtype)
        p["k_norm"] = L.rmsnorm_init(dh, dtype=dtype)
    return p


def project_cross_kv(p, enc_out, cfg):
    """Project encoder output to (k, v) for cross-attention (Whisper)."""
    return _project_kv(p, enc_out, cfg)


def _project_kv(p, x, cfg):
    b, t, _ = x.shape
    dh = cfg.resolved_head_dim
    k = L.dense_apply(p["wk"], x).reshape(b, t, cfg.n_kv_heads, dh)
    v = L.dense_apply(p["wv"], x).reshape(b, t, cfg.n_kv_heads, dh)
    k = constrain(k, "dp", None, "tp", None)   # kv heads stay head-sharded
    v = constrain(v, "dp", None, "tp", None)   # (or replicated if MQA-ish)
    if cfg.qk_norm:
        k = L.rmsnorm_apply(p["k_norm"], k)
    return k, v


def attn_apply(
    p,
    x: jnp.ndarray,               # [B, T, D]
    cfg,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    use_rope: Optional[bool] = None,
    cache: Optional[dict] = None,  # {"k","v"} [B, Tmax, Hkv, Dh] (+pos)
    cache_pos=None,                # write offset: scalar, or (B,) per-slot
                                   # vector (decode; pos < 0 = inactive slot,
                                   # cache row left untouched)
    n_valid=None,                  # (B,) count of valid tokens in a multi-
                                   # token per-slot chunk (speculative verify):
                                   # row writes past n_valid are dropped and
                                   # their keys masked; None = all t valid
    enc_kv: Optional[tuple] = None,  # cross-attn: precomputed (k, v)
    kv_table: Optional[jnp.ndarray] = None,  # (B, pages_per_slot) page table:
                                   # cache is a PAGE POOL {"k","v"[,"ks","vs"]}
                                   # of (P, page_size, Hkv, Dh) pages instead
                                   # of per-slot rows (decode only)
    policy: Optional[Policy] = None,
    backend: Optional[str] = None,   # deprecated string shim
):
    """Returns (out, new_cache). new_cache is None unless cache given.

    Kernel selection comes from `policy` (or the deprecated `backend`
    string, or the ambient policy): no-cache paths take the fused
    flash fwd/bwd pair, single-token cached steps take flash_decode,
    and masked prefill-into-cache stays on the chunked XLA path (see
    attention())."""
    pol = _resolve_attn_policy(policy, backend)
    b, t, _ = x.shape
    dh = cfg.resolved_head_dim
    use_rope = cfg.use_rope if use_rope is None else use_rope

    q = L.dense_apply(p["wq"], x).reshape(b, t, cfg.n_heads, dh)
    q = _constrain_bthd(q, cfg)
    if cfg.qk_norm:
        q = L.rmsnorm_apply(p["q_norm"], q)

    io_dtype = jnp.float32 if cfg.attn_f32_io else jnp.bfloat16

    if enc_kv is not None:                      # cross attention
        k, v = enc_kv
        out = attend(q, k, v, causal=False, window=None,
                     chunk=cfg.attn_chunk, policy=pol,
                     io_dtype=io_dtype)
        out = out.reshape(b, t, cfg.n_heads * dh)
        return L.dense_apply(p["wo"], out), None

    k, v = _project_kv(p, x, cfg)

    pos_vec = cache_pos is not None and jnp.asarray(cache_pos).ndim == 1

    if positions is None:
        off = cache_pos if cache_pos is not None else 0
        positions = L.default_positions(b, t, off)
    if use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if cache is not None and kv_table is not None:
        # Paged decode / verify: `cache` is this layer's page POOL, not
        # per-slot rows. Each slot's k/v row for chunk index j lands at
        # (table[slot, (pos+j)//ps], (pos+j)%ps) — the engine's
        # prepare_write has already made every written page privately
        # writable (CoW), so the scatter never touches shared bytes.
        # Inactive slots (pos < 0), rows past n_valid, and unmapped
        # table entries route out of bounds; mode="drop" skips them.
        assert pos_vec, "paged KV cache requires per-slot positions"
        pos = jnp.asarray(cache_pos, jnp.int32)
        n_pages, page_sz = cache["k"].shape[0], cache["k"].shape[1]
        bidx = jnp.arange(pos.shape[0])
        wpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None]  # (B,T)
        drop = pos[:, None] < 0
        if n_valid is not None:
            drop |= jnp.arange(t)[None] >= jnp.asarray(n_valid,
                                                       jnp.int32)[:, None]
        pj = jnp.where(drop, 0, wpos // page_sz)
        phys = kv_table[bidx[:, None], pj]
        phys = jnp.where(drop | (phys < 0), n_pages, phys)
        off = wpos % page_sz
        new_cache = dict(cache)
        if "ks" in cache:
            # int8 pages: quantize at page-write; the kernel dequantizes
            # on the f32 accumulator. Scale planes are (P, Hkv, ps) so a
            # page's scales sit lane-contiguous next to its rows.
            kq, ksc = _prec.quantize_kv(k)
            vq, vsc = _prec.quantize_kv(v)
            new_cache["k"] = cache["k"].at[phys, off].set(kq, mode="drop")
            new_cache["v"] = cache["v"].at[phys, off].set(vq, mode="drop")
            new_cache["ks"] = cache["ks"].at[phys, :, off].set(
                ksc, mode="drop")
            new_cache["vs"] = cache["vs"].at[phys, :, off].set(
                vsc, mode="drop")
        else:
            new_cache["k"] = cache["k"].at[phys, off].set(
                k.astype(cache["k"].dtype), mode="drop")
            new_cache["v"] = cache["v"].at[phys, off].set(
                v.astype(cache["v"].dtype), mode="drop")
        if t == 1:
            # Only pallas/xla have a paged gather; other backends
            # reroute to the dense XLA oracle (paged_gather_ref math).
            pol_r = pol if pol.backend in ("pallas", "xla") \
                else pol.replace(backend="xla")
            out = kops.flash_decode_paged(
                q, new_cache["k"], new_cache["v"], kv_table, pos=pos,
                window=cfg.window, ks=new_cache.get("ks"),
                vs=new_cache.get("vs"), policy=pol_r)
        else:
            # Multi-token verify (speculative decoding): gather each
            # slot's pages into a dense per-slot view (dequantizing int8
            # pages) and run the chunked masked path — exactly the dense
            # composition the paged kernel conformance-tests against.
            # The gather materializes (B, Tmax) rows once per verify
            # round; a paged multi-query kernel is the TPU follow-up.
            tclamp = jnp.maximum(kv_table, 0)
            kd = new_cache["k"][tclamp]       # (B, Ps, ps, Hkv, Dh)
            vd = new_cache["v"][tclamp]
            if "ks" in cache:
                ks = new_cache["ks"][tclamp].transpose(0, 1, 3, 2)
                vs = new_cache["vs"][tclamp].transpose(0, 1, 3, 2)
                kd = kd.astype(jnp.float32) * ks[..., None]
                vd = vd.astype(jnp.float32) * vs[..., None]
            b_, ps_ = tclamp.shape
            kd = kd.reshape(b_, ps_ * page_sz, cfg.n_kv_heads, dh)
            vd = vd.reshape(b_, ps_ * page_sz, cfg.n_kv_heads, dh)
            nv = jnp.asarray(t if n_valid is None else n_valid, jnp.int32)
            kv_len = jnp.where(pos < 0, 0, pos + nv)
            # pool width Ps*ps need not divide attn_chunk; page_sz does.
            ch = cfg.attn_chunk \
                if (ps_ * page_sz) % min(cfg.attn_chunk, ps_ * page_sz) == 0 \
                else page_sz
            out = attend(q, kd.astype(io_dtype), vd.astype(io_dtype),
                         causal=True, window=cfg.window,
                         chunk=ch, q_offset=pos,
                         kv_len=kv_len, io_dtype=io_dtype, policy=pol)
    elif cache is not None and pos_vec:
        # Continuous-batching decode (t == 1) or speculative verify
        # (t == k+1): each slot scatters its k/v rows at its own
        # positions — O(B*t) rows written, not O(cache). pos < 0
        # (inactive slot) and rows past n_valid map out of bounds and
        # mode="drop" skips the write entirely.
        pos = jnp.asarray(cache_pos, jnp.int32)
        bidx = jnp.arange(pos.shape[0])
        wpos = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None]  # (B,T)
        drop = pos[:, None] < 0
        if n_valid is not None:
            drop |= jnp.arange(t)[None] >= jnp.asarray(n_valid,
                                                       jnp.int32)[:, None]
        widx = jnp.where(drop, cache["k"].shape[1], wpos)
        ck = cache["k"].at[bidx[:, None], widx].set(
            k.astype(cache["k"].dtype), mode="drop")
        cv = cache["v"].at[bidx[:, None], widx].set(
            v.astype(cache["v"].dtype), mode="drop")
        new_cache = {"k": ck, "v": cv}
        nv = jnp.asarray(t if n_valid is None else n_valid, jnp.int32)
        kv_len = jnp.where(pos < 0, 0, pos + nv) if t > 1 else pos + 1
        # Per-row masks subsume the SWA fast path (window via mask).
        out = attend(q, ck, cv, causal=True, window=cfg.window,
                     chunk=cfg.attn_chunk, q_offset=pos,
                     kv_len=kv_len, io_dtype=io_dtype,
                     policy=pol, decode=(t == 1))
    elif cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        if cfg.window is not None and t == 1 and cache["k"].shape[1] > 2 * cfg.window:
            # SWA decode fast-path: only the last `window` cache entries
            # can attend — slice them out instead of scanning 500k keys.
            start = jnp.maximum(cache_pos + 1 - cfg.window, 0)
            kw = jax.lax.dynamic_slice_in_dim(ck, start, cfg.window, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(cv, start, cfg.window, axis=1)
            out = attend(q, kw, vw, causal=False, window=None,
                         chunk=cfg.attn_chunk,
                         kv_len=jnp.minimum(cache_pos + 1 - start,
                                            cfg.window),
                         io_dtype=io_dtype, policy=pol)
        else:
            out = attend(q, ck, cv, causal=True, window=cfg.window,
                         chunk=cfg.attn_chunk, q_offset=cache_pos,
                         kv_len=cache_pos + t, io_dtype=io_dtype,
                         policy=pol, decode=(t == 1))
    else:
        out = attend(q, k, v, causal=causal, window=cfg.window,
                     chunk=cfg.attn_chunk, policy=pol,
                     io_dtype=io_dtype)

    out = out.reshape(b, t, cfg.n_heads * dh)
    return L.dense_apply(p["wo"], out), new_cache
