"""Mixture-of-Experts with GShard-style capacity dispatch.

Chosen for scale-out behaviour: the dispatch/combine are einsums over a
one-hot (group, token, expert, slot) tensor, so under pjit the expert
dimension shards over the "model" axis (expert parallelism) and XLA
emits the all-to-alls — no torch-style manual routing. The expert GEMMs
are batched matmuls through the core.gemm chokepoint: the paper's tiled
kernel runs *inside* every expert.

Covers Mixtral (8e top-2) and Arctic (128e top-2 + parallel dense
residual branch).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import gemm
from repro.distributed.context import constrain
from repro.models import ffn as F
from repro.models import layers as L


def moe_init(key, cfg):
    mc = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, mc.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)

    def expert_bank(k, d_in, d_out, scale):
        w = jax.random.normal(k, (e, d_in, d_out), jnp.float32) * scale
        return w.astype(dtype)

    down_scale = f ** -0.5 / (2 * cfg.n_layers) ** 0.5
    p = {
        "router": L.dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": expert_bank(ks[1], d, f, d ** -0.5),
        "w_up": expert_bank(ks[2], d, f, d ** -0.5),
        "w_down": expert_bank(ks[3], f, d, down_scale),
    }
    if mc.dense_ff:
        p["dense"] = F.mlp_init(ks[4], cfg, d_ff=mc.dense_ff)
    return p


def _capacity(mc, s: int) -> int:
    c = int(mc.top_k * s * mc.capacity_factor / mc.n_experts)
    return max(4, c)


def _route(p, xg, mc):
    """Router: returns (probs, renormalised top-k probs, top-k ids,
    per-(g,s,e) capacity position, keep mask)."""
    e, k = mc.n_experts, mc.top_k
    g, s, _ = xg.shape
    c = _capacity(mc, s)
    logits = L.dense_apply(p["router"], xg.astype(jnp.float32))  # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                       # [G,S,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renorm

    counts = jnp.zeros((g, e), jnp.int32)
    pos_k, keep_k = [], []
    for kk in range(k):
        mask = jax.nn.one_hot(top_i[..., kk], e, dtype=jnp.int32)  # [G,S,E]
        pos = counts[:, None, :] + jnp.cumsum(mask, axis=1) - mask
        keep = (pos < c) & (mask > 0)
        pos_k.append(jnp.take_along_axis(
            pos, top_i[..., kk, None], axis=-1)[..., 0])           # [G,S]
        keep_k.append(jnp.take_along_axis(
            keep, top_i[..., kk, None], axis=-1)[..., 0])
        counts = counts + jnp.sum(mask, axis=1)
    return (logits, probs, top_p, top_i,
            jnp.stack(pos_k, -1), jnp.stack(keep_k, -1), c)


def _dispatch_gather(xg, top_i, top_p, pos, keep, e, c):
    """Index-based dispatch/combine: O(tokens*topk) bytes moved, no
    (G,S,E,C) one-hot tensors — the beyond-baseline schedule."""
    g, s, d = xg.shape
    k = top_i.shape[-1]
    gi = jnp.arange(g)[:, None]
    src = jnp.broadcast_to(jnp.arange(s)[None, :], (g, s))

    # slot -> source-token index; sentinel S reads the zero pad row
    idx = jnp.full((g, e, c), s, jnp.int32)
    for kk in range(k):
        pos_cl = jnp.where(keep[..., kk], pos[..., kk], c)  # OOB -> drop
        idx = idx.at[gi, top_i[..., kk], pos_cl].set(
            jnp.where(keep[..., kk], src, s), mode="drop")

    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    ex_in = x_pad[gi[..., None], idx]                  # [G,E,C,D]
    return ex_in, idx


def _combine_gather(ex_out_g, top_i, top_p, pos, keep, dtype):
    """ex_out_g: [G,E,C,D] -> per-token weighted sum over the k slots."""
    g, e, c, d = ex_out_g.shape
    k = top_i.shape[-1]
    s = top_i.shape[1]
    gi = jnp.arange(g)[:, None]
    out = jnp.zeros((g, s, d), jnp.float32)
    for kk in range(k):
        pos_cl = jnp.clip(pos[..., kk], 0, c - 1)
        slot = ex_out_g[gi, top_i[..., kk], pos_cl].astype(jnp.float32)
        wk = jnp.where(keep[..., kk], top_p[..., kk], 0.0)
        out = out + slot * wk[..., None]
    return out.astype(dtype)


def moe_apply(p, x, cfg):
    """x: [B, T, D]. Returns (out, aux) where aux carries router losses."""
    mc = cfg.moe
    b, t, d = x.shape
    e, k = mc.n_experts, mc.top_k
    # largest group size <= mc.group_size that divides the token count
    s = min(mc.group_size, b * t)
    while (b * t) % s:
        s -= 1
    g = (b * t) // s

    xg = x.reshape(g, s, d)
    xg = constrain(xg, "dp", None, None)
    logits, probs, top_p, top_i, pos, keep, c = _route(p, xg, mc)

    if mc.dispatch == "gather":
        ex_in, _ = _dispatch_gather(xg, top_i, top_p, pos, keep, e, c)
        ex_in = ex_in.transpose(1, 0, 2, 3).reshape(e, g * c, d)
    else:
        # GShard one-hot einsum dispatch (baseline; O(tokens*E*C) bytes)
        dispatch = jnp.zeros((g, s, e, c), dtype=x.dtype)
        for kk in range(k):
            slot = (jax.nn.one_hot(top_i[..., kk], e, dtype=x.dtype)[..., None]
                    * jax.nn.one_hot(pos[..., kk], c, dtype=x.dtype)[..., None, :]
                    * keep[..., kk, None, None].astype(x.dtype))
            dispatch = dispatch + slot
        ex_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg).reshape(e, g * c, d)
    # the G->E resharding below is the expert-parallel all-to-all
    ex_in = constrain(ex_in, "tp", "dp", None)

    # expert SwiGLU through the dual-GEMM chokepoint: on Pallas backends
    # each expert's gate/up GEMMs fuse into one kernel pass (vmapped
    # over the expert bank), eliminating both (E, G*C, F) intermediates.
    h = gemm.gated_mlp(ex_in, p["w_gate"].astype(ex_in.dtype),
                       p["w_up"].astype(ex_in.dtype))
    ex_out = gemm.matmul(h, p["w_down"].astype(h.dtype))
    ex_out = constrain(ex_out.reshape(e, g, c, d), "tp", "dp", None, None)

    if mc.dispatch == "gather":
        out = _combine_gather(ex_out.transpose(1, 0, 2, 3),
                              top_i, top_p, pos, keep, x.dtype)
    else:
        combine = jnp.zeros((g, s, e, c), dtype=x.dtype)
        for kk in range(k):
            slot = (jax.nn.one_hot(top_i[..., kk], e, dtype=x.dtype)[..., None]
                    * jax.nn.one_hot(pos[..., kk], c, dtype=x.dtype)[..., None, :]
                    * keep[..., kk, None, None].astype(x.dtype))
            combine = combine + slot * top_p[..., kk, None, None].astype(x.dtype)
        # bf16 operands + f32 accumulation: halves the dispatch/combine
        # collective bytes vs f32 upcast (§Perf mixtral it5)
        out = jnp.einsum("egcd,gsec->gsd", ex_out, combine,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, t, d)

    if mc.dense_ff:   # Arctic: parallel dense residual branch
        out = out + F.mlp_apply(
            p["dense"], x,
            dataclasses.replace(cfg, d_ff=mc.dense_ff))

    # Aux losses (Switch/GShard): load balance + router z-loss; plus the
    # dropped-token fraction as a monitored invariant.
    me = jnp.mean(probs, axis=(0, 1))                            # [E]
    ce = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "moe_lb_loss": lb_loss * mc.load_balance_coef,
        "moe_z_loss": z_loss * mc.router_z_coef,
        "moe_dropped_frac": dropped,
    }
    return out, aux
