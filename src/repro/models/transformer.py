"""Layer stacks for every assigned architecture family.

All stacks scan over layers (bounded HLO size => tractable 512-device
compiles) with a configurable remat policy, and all dense compute inside
every block routes through core.gemm — the paper's kernel under load.

Families:
  decoder   — dense / MoE / VLM decoder-only transformer
  ssm       — Mamba-2 stack (norm + mamba residual)
  hybrid    — Zamba2: Mamba-2 backbone + ONE weight-shared attention
              block invoked every `attn_every` layers with per-invocation
              LoRA deltas and concat([hidden, embed0]) input
  encdec    — Whisper: bidirectional encoder (stub conv frontend
              upstream) + causal decoder with cross-attention
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ----------------------------------------------------------------------
# remat policy
# ----------------------------------------------------------------------

def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)        # "full": save nothing


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return L.layernorm_init(d, dtype=jnp.dtype(cfg.param_dtype))
    return L.rmsnorm_init(d, dtype=jnp.dtype(cfg.param_dtype))


def _norm_apply(cfg, p, x):
    if cfg.norm == "ln":
        return L.layernorm_apply(p, x)
    return L.rmsnorm_apply(p, x)


# ----------------------------------------------------------------------
# decoder-only transformer block (dense / MoE)
# ----------------------------------------------------------------------

def block_init(key, cfg, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": _norm_init(cfg),
        "attn": A.attn_init(ks[0], cfg),
        "mlp_norm": _norm_init(cfg),
    }
    if cfg.moe is not None:
        p["moe"] = M.moe_init(ks[1], cfg)
    else:
        p["mlp"] = F.mlp_init(ks[1], cfg)
    if cross:
        p["cross_norm"] = _norm_init(cfg)
        p["cross_attn"] = A.attn_init(ks[2], cfg, cross=True)
    return p


def block_apply(p, x, cfg, *, positions=None, causal=True, cache=None,
                cache_pos=None, enc_out=None, cross_cache=None,
                kv_table=None, n_valid=None):
    """Returns (x, new_cache, aux)."""
    h, new_cache = A.attn_apply(
        p["attn"], _norm_apply(cfg, p["attn_norm"], x), cfg,
        positions=positions, causal=causal, cache=cache, cache_pos=cache_pos,
        kv_table=kv_table, n_valid=n_valid)
    x = x + h
    if enc_out is not None or cross_cache is not None:
        if cross_cache is not None:
            kv = (cross_cache["k"], cross_cache["v"])
        else:
            kv = A.project_cross_kv(p["cross_attn"], enc_out, cfg)
        hc, _ = A.attn_apply(
            p["cross_attn"], _norm_apply(cfg, p["cross_norm"], x), cfg,
            enc_kv=kv)
        x = x + hc
    x = constrain(x, "dp", None, None)
    aux = {}
    if cfg.moe is not None:
        h, aux = M.moe_apply(p["moe"], _norm_apply(cfg, p["mlp_norm"], x), cfg)
        out = x + h
    else:
        # the skip connection rides the down-projection's fused flush on
        # Pallas backends (residual epilogue); identical composition on xla
        out = F.mlp_apply(p["mlp"], _norm_apply(cfg, p["mlp_norm"], x), cfg,
                          residual=x)
    return constrain(out, "dp", None, None), new_cache, aux


# ----------------------------------------------------------------------
# stacked decoder (scan over layers)
# ----------------------------------------------------------------------

def stack_init(key, cfg, *, n_layers=None, cross=False):
    n = n_layers or cfg.n_layers
    keys = jax.random.split(key, n)
    if cfg.scan_layers:
        return jax.vmap(lambda k: block_init(k, cfg, cross=cross))(keys)
    return [block_init(k, cfg, cross=cross) for k in keys]


def stack_apply(params, x, cfg, *, positions=None, causal=True,
                caches=None, cache_pos=None, enc_out=None,
                cross_caches=None, kv_table=None, n_valid=None):
    """caches / cross_caches carry a leading layer dim when scanning.

    kv_table (paged decode) is closed over rather than scanned: one
    logical page is the same physical index in every layer's pool, so
    the table has no layer dim to carry as an xs. n_valid (per-row valid
    token count, speculative verify) is likewise layer-less and closed
    over.

    Returns (x, new_caches, aux_sum).
    """
    def body(carry, layer_in):
        xc, aux_sum = carry
        lp, cache, ccache = layer_in
        xo, new_cache, aux = block_apply(
            lp, xc, cfg, positions=positions, causal=causal, cache=cache,
            cache_pos=cache_pos, enc_out=enc_out, cross_cache=ccache,
            kv_table=kv_table, n_valid=n_valid)
        aux_sum = {k: aux_sum.get(k, 0.0) + v for k, v in aux.items()} \
            if aux else aux_sum
        return (xo, aux_sum), new_cache

    aux0 = {}
    if cfg.moe is not None:
        zero = jnp.zeros((), jnp.float32)
        aux0 = {"moe_lb_loss": zero, "moe_z_loss": zero,
                "moe_dropped_frac": zero}

    if cfg.scan_layers:
        body_r = _maybe_remat(body, cfg)
        (x, aux), new_caches = jax.lax.scan(
            body_r, (x, aux0), (params, caches, cross_caches))
    else:
        new_list = []
        carry = (x, aux0)
        n = len(params)
        for i in range(n):
            carry, nc = body(carry, (
                params[i],
                None if caches is None else jax.tree.map(lambda c: c[i], caches),
                None if cross_caches is None else jax.tree.map(
                    lambda c: c[i], cross_caches)))
            new_list.append(nc)
        x, aux = carry
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
                      if new_list and new_list[0] is not None else None)
    if cfg.moe is not None and aux:
        aux = dict(aux)
        aux["moe_dropped_frac"] = aux["moe_dropped_frac"] / cfg.n_layers
    return x, new_caches, aux


# ----------------------------------------------------------------------
# Mamba-2 stack
# ----------------------------------------------------------------------

def ssm_stack_init(key, cfg):
    keys = jax.random.split(key, cfg.n_layers)

    def one(k):
        return {"norm": _norm_init(cfg), "mamba": S.mamba_init(k, cfg)}
    if cfg.scan_layers:
        return jax.vmap(one)(keys)
    return [one(k) for k in keys]


def ssm_stack_apply(params, x, cfg, *, states=None, decode=False):
    """states: stacked mamba states (leading L dim). decode => 1 token."""
    collect = states is not None and not decode

    def body(xc, layer_in):
        lp, st = layer_in
        xin = _norm_apply(cfg, lp["norm"], xc)
        if decode:
            h, new_st = S.mamba_decode(lp["mamba"], xin, cfg, st)
        else:
            h, new_st = S.mamba_apply(lp["mamba"], xin, cfg,
                                      return_state=collect)
        return xc + h, new_st

    body_r = _maybe_remat(body, cfg) if not decode else body
    x, new_states = jax.lax.scan(body_r, x, (params, states))
    return x, new_states


# ----------------------------------------------------------------------
# Zamba2 hybrid stack
# ----------------------------------------------------------------------

def hybrid_init(key, cfg):
    assert cfg.attn_every > 0
    n_seg = cfg.n_layers // cfg.attn_every
    ks = jax.random.split(key, 4)
    # mamba layers stacked as (n_seg, per_seg, ...)
    keys = jax.random.split(ks[0], cfg.n_layers)

    def one(k):
        return {"norm": _norm_init(cfg), "mamba": S.mamba_init(k, cfg)}
    mamba = jax.vmap(one)(keys)
    mamba = jax.tree.map(
        lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:]), mamba)

    shared_cfg = dataclasses.replace(cfg, moe=None)
    shared = {
        "in_proj": L.dense_init(ks[1], 2 * cfg.d_model, cfg.d_model,
                                dtype=jnp.dtype(cfg.param_dtype)),
        "block": block_init(ks[2], shared_cfg),
    }
    p = {"mamba": mamba, "shared": shared}
    r = cfg.shared_attn_lora_rank
    if r:
        dh = cfg.resolved_head_dim
        ka, kb = jax.random.split(ks[3])
        p["lora_a"] = (jax.random.normal(
            ka, (n_seg, cfg.d_model, r), jnp.float32) * cfg.d_model ** -0.5
        ).astype(jnp.dtype(cfg.param_dtype))
        p["lora_b"] = jnp.zeros((n_seg, r, cfg.n_heads * dh),
                                jnp.dtype(cfg.param_dtype))
    return p


def hybrid_apply(params, x, cfg, *, emb0, attn_caches=None, cache_pos=None,
                 mamba_states=None, decode=False):
    """emb0: the initial embedding, concat-fed to every shared-block call.

    attn_caches: stacked (n_seg, B, Tmax, Hkv, Dh) KV caches.
    Returns (x, new_attn_caches, new_mamba_states).
    """
    n_seg = cfg.n_layers // cfg.attn_every
    shared_cfg = dataclasses.replace(cfg, moe=None)
    collect = mamba_states is not None and not decode

    def seg_body(carry, seg_in):
        xc = carry
        seg_params, seg_states, attn_cache, lora = seg_in

        def layer_body(xi, layer_in):
            lp, st = layer_in
            xin = _norm_apply(cfg, lp["norm"], xi)
            if decode:
                h, new_st = S.mamba_decode(lp["mamba"], xin, cfg, st)
            else:
                h, new_st = S.mamba_apply(lp["mamba"], xin, cfg,
                                          return_state=collect)
            return xi + h, new_st

        lb = _maybe_remat(layer_body, cfg) if not decode else layer_body
        xc, new_seg_states = jax.lax.scan(lb, xc, (seg_params, seg_states))

        # shared attention block on concat(hidden, first-embedding)
        xin = L.dense_apply(params["shared"]["in_proj"],
                            jnp.concatenate([xc, emb0], axis=-1))
        bp = params["shared"]["block"]
        if lora is not None:
            la, lbm = lora
            delta = jnp.einsum("btd,dr,rh->bth",
                               _norm_apply(cfg, bp["attn_norm"], xin),
                               la.astype(xin.dtype), lbm.astype(xin.dtype))
        else:
            delta = None
        xo, new_cache, _ = block_apply(
            bp, xin, shared_cfg, cache=attn_cache, cache_pos=cache_pos)
        if delta is not None:
            xo = xo + delta
        return xc + xo, (new_seg_states, new_cache)

    lora_xs = None
    if cfg.shared_attn_lora_rank:
        lora_xs = (params["lora_a"], params["lora_b"])
    seg_in = (params["mamba"], mamba_states, attn_caches, lora_xs)
    x, (new_states, new_caches) = jax.lax.scan(seg_body, x, seg_in)
    return x, new_caches, new_states
