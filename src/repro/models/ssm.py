"""Mamba-2 (SSD, state-space duality) blocks.

The SSD dual form is itself a *blocked matmul algorithm*: the sequence
is chunked, intra-chunk terms are dense (decay-masked) matmuls and
inter-chunk terms are a rank-N state recurrence — i.e. the paper's
tiling idea applied along time. This makes mamba2-2.7b the assigned
architecture that most directly exercises the contribution (DESIGN §6).

Shapes follow the Mamba-2 paper: d_inner = expand*d_model, H heads of
size P=head_dim, G state groups of size N=d_state, short causal
depthwise conv of width W over the (x, B, C) channels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ssd as _core_ssd
from repro.distributed.context import constrain
from repro.kernels.ssd import _segsum, ssd_chunked   # noqa: F401  (compat)
from repro.models import layers as L


# ----------------------------------------------------------------------
# Mamba-2 block
# ----------------------------------------------------------------------

def _dims(cfg, d_model=None):
    sc = cfg.ssm
    d = d_model or cfg.d_model
    d_inner = sc.expand * d
    h = d_inner // sc.head_dim
    conv_dim = d_inner + 2 * sc.n_groups * sc.d_state
    return d, d_inner, h, conv_dim


def mamba_init(key, cfg, *, d_model=None):
    """Two projections, not one: z/x (wide, TP-sharded over "model") and
    B/C/dt (narrow, replicated). A single fused in_proj shards its
    output dim over "model", which strands the 2GN B/C channels on one
    shard and forces a per-layer broadcast — measured as the dominant
    collective on mamba2 prefill (EXPERIMENTS §Perf it4)."""
    sc = cfg.ssm
    d, d_inner, h, conv_dim = _dims(cfg, d_model)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    gn2 = 2 * sc.n_groups * sc.d_state
    dt = jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner, dtype=dtype),
        "in_proj_bc": L.dense_init(ks[4], d, gn2 + h, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (sc.conv_width, d_inner),
                                     jnp.float32)
                   * (sc.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[3], (sc.conv_width, gn2),
                                        jnp.float32)
                      * (sc.conv_width ** -0.5)).astype(dtype),
        "conv_bc_b": jnp.zeros((gn2,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "norm": L.rmsnorm_init(d_inner, dtype=dtype),
        "out_proj": L.dense_init(ks[5], d_inner, d, dtype=dtype,
                                 scale=d_inner ** -0.5
                                 / (2 * cfg.n_layers) ** 0.5),
    }


def _project(p, x, cfg, d_model=None):
    """-> (z, x_pre_conv, bc_pre_conv, dt_raw)."""
    sc = cfg.ssm
    _, d_inner, h, _ = _dims(cfg, d_model)
    zx = L.dense_apply(p["in_proj"], x)
    bcdt = L.dense_apply(p["in_proj_bc"], x)
    z = zx[..., :d_inner]
    xs = zx[..., d_inner:]
    bc = bcdt[..., :-h]
    dt = bcdt[..., -h:]
    return z, xs, bc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B, L, C) with weight (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(width))
    return out + b[None, None, :]


def mamba_apply(p, x, cfg, *, d_model=None, return_state: bool = False):
    """Full-sequence (train / prefill) pass. x: (B, L, D)."""
    sc = cfg.ssm
    _, d_inner, h, conv_dim = _dims(cfg, d_model)
    bsz, l, _ = x.shape
    gn = sc.n_groups * sc.d_state

    z, xs_pre, bc_pre, dt_raw = _project(p, x, cfg, d_model)
    z = constrain(z, "dp", None, "tp")
    xs_pre = constrain(xs_pre, "dp", None, "tp")
    bc_pre = constrain(bc_pre, "dp", None, None)     # replicated (tiny)
    xsc = jax.nn.silu(_causal_conv(xs_pre, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    bcc = jax.nn.silu(_causal_conv(bc_pre, p["conv_bc_w"].astype(x.dtype),
                                   p["conv_bc_b"].astype(x.dtype)))
    xs = xsc.reshape(bsz, l, h, sc.head_dim)
    xs = constrain(xs, "dp", None, "tp", None)
    b_ = bcc[..., :gn].reshape(bsz, l, sc.n_groups, sc.d_state)
    c_ = bcc[..., gn:].reshape(bsz, l, sc.n_groups, sc.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])        # (B,L,H)
    a_neg = -jnp.exp(p["A_log"])[None, None, :] * dt           # (B,L,H)

    # pad L to a chunk multiple; dt=0 makes pad steps exact identities
    # for the recurrence (decay exp(0)=1, zero state contribution).
    chunk = min(sc.chunk, l)
    pad = (-l) % chunk
    xdt = xs.astype(jnp.float32) * dt[..., None]
    bf = b_.astype(jnp.float32)
    cf = c_.astype(jnp.float32)
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        xdt = jnp.pad(xdt, pad4)
        bf = jnp.pad(bf, pad4)
        cf = jnp.pad(cf, pad4)
        a_neg = jnp.pad(a_neg, ((0, 0), (0, pad), (0, 0)))

    # tagged for the roofline analyzer: the chunk-interior tensors
    # (decay masks, CB scores) are VMEM-resident in a fused SSD kernel
    # (the Mamba-2 paper's own kernel design; our Pallas analogue is the
    # §Perf substitution model).
    with jax.named_scope("ssdsite"):
        y, s_final = _core_ssd.ssd(xdt, a_neg, bf, cf, chunk)
    y = y[:, :l]
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)
    y = constrain(y, "dp", None, "tp")
    y = L.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = constrain(L.dense_apply(p["out_proj"], y), "dp", None, None)
    if not return_state:
        return out, None
    # conv caches: last (W-1) *pre-conv* channel values. Prompts shorter
    # than W-1 left-pad the *projected* tail with zeros — matching the
    # zero conv buffers of mamba_init_state, which is exactly what the
    # running conv would hold after only `l` tokens. (Padding x before
    # projection would be wrong: a biased dense of zeros is not zero.)
    w1 = sc.conv_width - 1
    tail = x[:, -w1:]
    _, xs_tail, bc_tail, _ = _project(p, tail, cfg, d_model)
    if tail.shape[1] < w1:
        padn = w1 - tail.shape[1]
        pad3 = ((0, 0), (padn, 0), (0, 0))
        xs_tail = jnp.pad(xs_tail, pad3)
        bc_tail = jnp.pad(bc_tail, pad3)
    return out, {"ssd": s_final, "conv": xs_tail, "conv_bc": bc_tail}


def mamba_init_state(cfg, bsz, *, d_model=None, dtype=jnp.float32):
    sc = cfg.ssm
    _, d_inner, h, conv_dim = _dims(cfg, d_model)
    gn2 = 2 * sc.n_groups * sc.d_state
    return {
        "ssd": jnp.zeros((bsz, h, sc.head_dim, sc.d_state), jnp.float32),
        "conv": jnp.zeros((bsz, sc.conv_width - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((bsz, sc.conv_width - 1, gn2), dtype),
    }


def mamba_decode(p, x_t, cfg, state, *, d_model=None):
    """Single-token step. x_t: (B, 1, D); state keys: ssd/conv/conv_bc."""
    sc = cfg.ssm
    _, d_inner, h, conv_dim = _dims(cfg, d_model)
    bsz = x_t.shape[0]
    gn = sc.n_groups * sc.d_state

    z, xs_new, bc_new, dt_raw = _project(p, x_t, cfg, d_model)

    def conv_step(buf, new, w, bias):
        cat = jnp.concatenate([buf, new.astype(buf.dtype)], axis=1)
        out = jnp.einsum("bwc,wc->bc", cat.astype(x_t.dtype),
                         w.astype(x_t.dtype))
        return jax.nn.silu(out + bias.astype(x_t.dtype)), cat[:, 1:]

    xbc, new_conv = conv_step(state["conv"], xs_new, p["conv_w"],
                              p["conv_b"])
    bcc, new_conv_bc = conv_step(state["conv_bc"], bc_new, p["conv_bc_w"],
                                 p["conv_bc_b"])

    xs = xbc.reshape(bsz, h, sc.head_dim)
    b_ = bcc[:, :gn].reshape(bsz, sc.n_groups, sc.d_state)
    c_ = bcc[:, gn:].reshape(bsz, sc.n_groups, sc.d_state)
    rep = h // sc.n_groups
    b_h = jnp.repeat(b_, rep, axis=1).astype(jnp.float32)      # (B,H,N)
    c_h = jnp.repeat(c_, rep, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"][None, :])              # (B,H)
    da = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)           # (B,H)
    xf = xs.astype(jnp.float32) * dt[..., None]                # (B,H,P)

    s = state["ssd"] * da[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", b_h, xf)
    y = jnp.einsum("bhn,bhpn->bhp", c_h, s)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_inner).astype(x_t.dtype)
    y = L.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = L.dense_apply(p["out_proj"], y)
    return out, {"ssd": s, "conv": new_conv, "conv_bc": new_conv_bc}
