"""Feed-forward blocks: SwiGLU / GELU MLPs. All GEMMs route through the
core.gemm chokepoint (the paper's kernel under every FFN).

The hot path is fused on Pallas backends: SwiGLU's gate/up GEMMs run as
one dual-GEMM kernel (`gemm.gated_mlp` — no (M, d_ff) intermediates in
HBM), the GELU MLP's bias+activation ride the up-projection's flush
phase, and the block residual can ride the down-projection
(`residual=`). On xla the same compositions run unfused — numerics are
backend-checked in tests/test_fused_epilogue.py."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def mlp_init(key, cfg, *, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    down_scale = f ** -0.5 / (2 * cfg.n_layers) ** 0.5
    if cfg.mlp == "swiglu":
        return {
            "w_gate": L.dense_init(ks[0], d, f, dtype=dtype),
            "w_up": L.dense_init(ks[1], d, f, dtype=dtype),
            "w_down": L.dense_init(ks[2], f, d, dtype=dtype, scale=down_scale),
        }
    return {
        "w_in": L.dense_init(ks[0], d, f, dtype=dtype, bias=True),
        "w_out": L.dense_init(ks[1], f, d, dtype=dtype, bias=True,
                              scale=down_scale),
    }


def mlp_apply(p, x, cfg, *, residual=None):
    """residual (e.g. the block's skip connection) is fused into the
    down-projection's flush where the epilogue lattice allows."""
    if cfg.mlp == "swiglu":
        h = L.gated_apply(p["w_gate"], p["w_up"], x)
        return L.dense_apply(p["w_down"], h, residual=residual)
    h = L.dense_apply(p["w_in"], x, activation="gelu")
    return L.dense_apply(p["w_out"], h, residual=residual)
