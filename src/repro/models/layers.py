"""Shared building blocks: norms, embeddings, rotary (incl. M-RoPE).

Module style: pure init/apply function pairs over plain-dict pytrees —
no framework dependency, stable param paths for the sharding rules and
the checkpointer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gemm
from repro.core import precision


def dense_init(key, d_in: int, d_out: int, *, dtype, scale: float | None = None,
               bias: bool = False):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_quantize(p, spec: precision.QuantSpec | None = None):
    """Quantize one dense param dict: {"w": float, "b"?} ->
    {"w_q": int8, "w_scale": f32 per-channel, "b"?}. dense_apply /
    gated_apply detect the quantized keys and route through
    gemm.dense_q. Works on scanned stacks too: a (L, K, N) weight
    yields (L, 1, N) scales that scan slices alongside the int8 leaf."""
    spec = spec or precision.QuantSpec()
    q, s = precision.quantize(p["w"], spec)
    out = {"w_q": q, "w_scale": s}
    if "b" in p:
        out["b"] = p["b"]
    return out


def dense_apply(p, x, *, out_dtype=None, activation=None, residual=None):
    """activation/residual ride the kernel's fused flush phase on Pallas
    backends (core.gemm.dense epilogue routing)."""
    if "w_q" in p:
        return gemm.dense_q(x, p["w_q"], p["w_scale"], p.get("b"),
                            activation=activation, residual=residual,
                            out_dtype=out_dtype)
    return gemm.dense(x, p["w"].astype(x.dtype), p.get("b"),
                      activation=activation, residual=residual,
                      out_dtype=out_dtype)


def gated_apply(p_gate, p_up, x, *, out_dtype=None):
    """SwiGLU hidden phase through the dual-GEMM chokepoint. Quantized
    weights decompose into two dense_q GEMMs + the elementwise gate (the
    dual-GEMM kernel has no int8 variant yet — the weight-traffic win is
    identical, only the A-stream sharing is lost)."""
    if "w_q" in p_gate:
        g = gemm.dense_q(x, p_gate["w_q"], p_gate["w_scale"],
                         out_dtype=out_dtype)
        u = gemm.dense_q(x, p_up["w_q"], p_up["w_scale"],
                         out_dtype=out_dtype)
        return (jax.nn.silu(g) * u).astype(g.dtype)
    return gemm.gated_mlp(x, p_gate["w"].astype(x.dtype),
                          p_up["w"].astype(x.dtype), out_dtype=out_dtype)


def rmsnorm_init(d: int, *, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, *, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed_init(key, vocab: int, d: int, *, dtype):
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * (d ** -0.5)
    return {"w": w.astype(dtype)}


def embed_apply(p, ids, *, dtype):
    return jnp.take(p["w"], ids, axis=0).astype(dtype)


def embed_attend(p, x):
    """Tied-embedding logits: x @ W^T through the GEMM chokepoint."""
    return gemm.matmul(x, p["w"].astype(x.dtype).T, out_dtype=jnp.float32)


def sinusoid_positions(t: int, d: int, offset: int = 0) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (f32)."""
    pos = jnp.arange(offset, offset + t)[:, None].astype(jnp.float32)
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, d, 2) / d)
    pe = jnp.zeros((t, d), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ----------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections=None) -> jnp.ndarray:
    """x: [B, T, H, D]; positions: [B, T] or [B, T, 3] (M-RoPE).

    M-RoPE (Qwen2-VL): the head_dim/2 frequency slots are partitioned
    into (temporal, height, width) sections, each rotated by its own
    position stream. Text tokens carry t=h=w so M-RoPE degenerates to
    RoPE exactly — property-tested in tests/test_layers.py.
    """
    b, t, h, d = x.shape
    freqs = rope_freqs(d, theta)                        # [d/2]
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,T,d/2]
    else:
        assert mrope_sections is not None
        ang_parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            ang_parts.append(
                positions[..., i, None].astype(jnp.float32)
                * freqs[start:start + sec])
            start += sec
        assert start == d // 2, (mrope_sections, d)
        ang = jnp.concatenate(ang_parts, axis=-1)       # [B,T,d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def default_positions(b: int, t: int, offset=0) -> jnp.ndarray:
    """offset: scalar (uniform batch) or (B,) per-slot position vector."""
    off = jnp.asarray(offset, jnp.int32)
    pos = jnp.arange(t, dtype=jnp.int32)[None] + \
        (off[:, None] if off.ndim else off)
    return jnp.broadcast_to(pos, (b, t))
