"""Benchmark-driven tile autotuning for the Pallas kernels.

The paper's speedup is a function of block size; this package makes
block size a measured quantity instead of a constant. See
docs/ARCHITECTURE.md §Autotuning for the subsystem map and
EXPERIMENTS.md §Autotune for the cache format.

Layering: cache/space/timing are leaves (kernels.ops may import them);
autotuner sits above kernels.ops and is loaded lazily here so that
`kernels.ops -> tuning.cache` never cycles back through this package.
"""

from repro.tuning.cache import (CACHE_ENV_VAR, TuningCache,
                                default_cache_path, flash_bwd_key,
                                flash_decode_key, flash_decode_paged_key,
                                flash_key, gated_key,
                                get_cache, matmul_key, reset_cache,
                                set_cache, ssd_key)
from repro.tuning.space import (flash_bwd_candidates, flash_candidates,
                                flash_decode_candidates,
                                flash_decode_paged_candidates,
                                gated_matmul_candidates, matmul_candidates,
                                ssd_candidates)
from repro.tuning.timing import time_jax

_LAZY = ("TuneResult", "default_exec_backend", "default_exec_policy",
         "describe_warm_start", "model_attention_shapes",
         "model_gemm_shapes", "model_ssd_shapes", "tune_flash_attention",
         "tune_flash_bwd", "tune_flash_decode", "tune_flash_decode_paged",
         "tune_gated_matmul", "tune_matmul", "tune_ssd", "warm_start")

__all__ = [
    "CACHE_ENV_VAR", "TuningCache", "default_cache_path", "flash_bwd_key",
    "flash_decode_key", "flash_decode_paged_key", "flash_key",
    "gated_key", "get_cache", "matmul_key", "reset_cache", "set_cache",
    "ssd_key",
    "flash_bwd_candidates", "flash_candidates", "flash_decode_candidates",
    "flash_decode_paged_candidates",
    "gated_matmul_candidates", "matmul_candidates", "ssd_candidates",
    "time_jax", *_LAZY,
]


def __getattr__(name):
    if name in _LAZY:
        from repro.tuning import autotuner
        return getattr(autotuner, name)
    raise AttributeError(name)
