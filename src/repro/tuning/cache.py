"""Persistent winner cache for the tile autotuner.

One JSON file holds tuned tile configs for any number of machines,
namespaced by hardware fingerprint (core.hw.fingerprint):

    {
      "version": 1,
      "caches": {
        "<fingerprint>": {
          "matmul|4096x4096x4096|float32|pallas": {
            "bm": 512, "bn": 512, "bk": 1024,
            "time_us": 812.4, "baseline_us": 1103.9,
            "speedup": 1.36, "tuned_at": "2026-07-29T12:00:00"
          },
          "flash|2048x2048xd64|bfloat16|pallas": {
            "bq": 512, "bk": 512, ...
          }
        }
      }
    }

Lookups under a fingerprint that is not in the file (new chip, new jax,
interpret-vs-compiled) return None and the caller falls back to the
static chooser in core.blocking — a stale cache can never mis-tile a
different machine. The full format is documented in docs/ARCHITECTURE.md
and EXPERIMENTS.md §Autotune.

This module is import-light on purpose: kernels/ops.py consults it on
every tuned-backend call, so it depends only on repro.core.
"""

from __future__ import annotations

import datetime
import json
import os
import threading
from typing import Any, Optional

import numpy as np

from repro.core import hw
from repro.core.blocking import BlockConfig, FlashBlockConfig, SSDBlockConfig

CACHE_VERSION = 1
CACHE_ENV_VAR = "REPRO_TUNING_CACHE"
DEFAULT_CACHE_PATH = "~/.cache/repro/tuning.json"


def default_cache_path() -> str:
    return os.path.expanduser(os.environ.get(CACHE_ENV_VAR, DEFAULT_CACHE_PATH))


def _backend_tag(backend) -> str:
    """Key component naming the execution backend. Accepts a
    core.policy.Policy (preferred — the tag is its kernel_fingerprint,
    i.e. the execution-relevant backend+interpret fields) or a legacy
    string. The fingerprint of a Policy matches the historical string
    spellings ("pallas", "pallas_interpret"), so caches written before
    the Policy refactor keep serving."""
    fp = getattr(backend, "kernel_fingerprint", backend)
    if not isinstance(fp, str):
        raise TypeError(f"expected Policy or backend string, got "
                        f"{type(backend)}")
    return fp


def matmul_key(m: int, n: int, k: int, dtype, backend,
               epilogue: str = "none") -> str:
    """Fused-epilogue variants are keyed separately: the extra flush-
    phase operand DMA and VPU work shift the optimal tile, so a winner
    tuned for the plain GEMM must not be served to e.g. bias_silu.
    epilogue="none" keeps the historical key so old caches stay valid."""
    key = f"matmul|{m}x{n}x{k}|{np.dtype(dtype).name}|{_backend_tag(backend)}"
    if epilogue not in (None, "none"):
        key += f"|{epilogue}"
    return key


def matmul_q_key(m: int, n: int, k: int, dtype, backend,
                 epilogue: str = "none") -> str:
    """Int8-weight GEMM winners (kernels.ops.matmul_q). `dtype` is the
    ACTIVATION dtype — the weight is int8 by definition of the op. A
    Policy's quant field is normalised to "int8" before tagging so an
    explicit ops.matmul_q call and a quant-policy-routed dense_q call
    share one entry population; the int8-cost-model tiles must never be
    served to the full-width kernel (and vice versa), which the op
    prefix plus the fingerprint's _int8 suffix both enforce."""
    if getattr(backend, "quant", None) == "off":
        backend = backend.replace(quant="int8")
    key = (f"matmul_q|{m}x{n}x{k}|{np.dtype(dtype).name}|"
           f"{_backend_tag(backend)}")
    if epilogue not in (None, "none"):
        key += f"|{epilogue}"
    return key


def gated_key(m: int, n: int, k: int, dtype, backend) -> str:
    """The dual-GEMM SwiGLU kernel: (m, k) x 2*(k, n) -> (m, n)."""
    return f"gated|{m}x{n}x{k}|{np.dtype(dtype).name}|{_backend_tag(backend)}"


def flash_key(tq: int, tk: int, d: int, dtype, backend) -> str:
    return f"flash|{tq}x{tk}xd{d}|{np.dtype(dtype).name}|{_backend_tag(backend)}"


def flash_decode_key(tk: int, d: int, dtype, backend) -> str:
    """The decode kernel is q_len=1 by construction, so its shape key is
    just (cache depth, head dim) — every slot depth shares one entry
    (pos streams as data, not a trace constant)."""
    return (f"flash_decode|{tk}xd{d}|{np.dtype(dtype).name}|"
            f"{_backend_tag(backend)}")


def flash_decode_paged_key(page_size: int, d: int, dtype, backend) -> str:
    """The paged decode kernel's tile space is keyed by (page_size,
    head_dim), not cache depth: bk must divide the page (one pool page
    — or a sub-tile of it — per grid step), so the same winner serves
    every pool size and slot count. The op prefix keeps these entries
    disjoint from dense flash_decode winners."""
    return (f"flash_decode_paged|p{page_size}xd{d}|{np.dtype(dtype).name}|"
            f"{_backend_tag(backend)}")


def ssd_key(chunk: int, p: int, n: int, dtype, backend) -> str:
    """SSD winners are keyed by (model chunk, head dim P, state dim N):
    chunking is algebraically exact, so the execution tile (q, bp) is a
    pure perf knob and any sequence length padded to the same model
    chunk shares one entry — L is deliberately absent from the key,
    like pos in flash_decode's."""
    return (f"ssd|Q{chunk}xP{p}xN{n}|{np.dtype(dtype).name}|"
            f"{_backend_tag(backend)}")


def flash_bwd_key(tq: int, tk: int, d: int, dtype, backend) -> str:
    """Backward winners get their own population: the two-sweep bwd
    kernel's working set (dK/dV accumulators + q/do/lse/delta streams)
    shifts the optimum away from the forward's."""
    return (f"flash_bwd|{tq}x{tk}xd{d}|{np.dtype(dtype).name}|"
            f"{_backend_tag(backend)}")


class TuningCache:
    """In-memory view of one fingerprint's entries, backed by the JSON
    file. `save()` is read-modify-write so caches for other fingerprints
    sharing the file survive."""

    def __init__(self, path: str | None = None,
                 fingerprint: str | None = None):
        self.path = path or default_cache_path()
        self.fingerprint = fingerprint or hw.fingerprint()
        self._entries: dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # --- persistence -----------------------------------------------------
    def load(self) -> "TuningCache":
        with self._lock:
            doc = self._read_file()
            if self._newer_format(doc):
                self._entries = {}    # unreadable to us; lookups miss
            else:
                self._entries = dict(
                    doc.get("caches", {}).get(self.fingerprint, {}))
        return self

    def save(self) -> str:
        with self._lock:
            doc = self._read_file()
            if self._newer_format(doc):
                raise RuntimeError(
                    f"{self.path} was written by a newer cache format "
                    f"(version {doc['version']} > {CACHE_VERSION}); refusing "
                    "to overwrite it — set REPRO_TUNING_CACHE to a fresh path")
            doc["version"] = CACHE_VERSION
            doc.setdefault("caches", {}).setdefault(
                self.fingerprint, {}).update(self._entries)
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        return self.path

    @staticmethod
    def _newer_format(doc: dict) -> bool:
        return doc.get("version", CACHE_VERSION) > CACHE_VERSION

    def _read_file(self) -> dict:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return {}
        return doc if isinstance(doc, dict) else {}

    # --- raw access ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> dict[str, dict]:
        return dict(self._entries)

    def get(self, key: str) -> Optional[dict]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        self._entries[key] = dict(entry)

    # --- typed accessors -------------------------------------------------
    def get_matmul(self, m: int, n: int, k: int, dtype, backend,
                   epilogue: str = "none") -> Optional[BlockConfig]:
        e = self.get(matmul_key(m, n, k, dtype, backend, epilogue))
        if e is None:
            return None
        return BlockConfig(bm=int(e["bm"]), bn=int(e["bn"]), bk=int(e["bk"]))

    def put_matmul(self, m: int, n: int, k: int, dtype, backend,
                   cfg: BlockConfig, *, epilogue: str = "none",
                   **meta: Any) -> str:
        key = matmul_key(m, n, k, dtype, backend, epilogue)
        self.put(key, {"bm": cfg.bm, "bn": cfg.bn, "bk": cfg.bk,
                       "tuned_at": _now(), **meta})
        return key

    def get_matmul_q(self, m: int, n: int, k: int, dtype, backend,
                     epilogue: str = "none") -> Optional[BlockConfig]:
        e = self.get(matmul_q_key(m, n, k, dtype, backend, epilogue))
        if e is None:
            return None
        return BlockConfig(bm=int(e["bm"]), bn=int(e["bn"]), bk=int(e["bk"]))

    def put_matmul_q(self, m: int, n: int, k: int, dtype, backend,
                     cfg: BlockConfig, *, epilogue: str = "none",
                     **meta: Any) -> str:
        key = matmul_q_key(m, n, k, dtype, backend, epilogue)
        self.put(key, {"bm": cfg.bm, "bn": cfg.bn, "bk": cfg.bk,
                       "tuned_at": _now(), **meta})
        return key

    def get_gated(self, m: int, n: int, k: int, dtype,
                  backend) -> Optional[BlockConfig]:
        e = self.get(gated_key(m, n, k, dtype, backend))
        if e is None:
            return None
        return BlockConfig(bm=int(e["bm"]), bn=int(e["bn"]), bk=int(e["bk"]))

    def put_gated(self, m: int, n: int, k: int, dtype, backend,
                  cfg: BlockConfig, **meta: Any) -> str:
        key = gated_key(m, n, k, dtype, backend)
        self.put(key, {"bm": cfg.bm, "bn": cfg.bn, "bk": cfg.bk,
                       "tuned_at": _now(), **meta})
        return key

    def get_flash(self, tq: int, tk: int, d: int, dtype,
                  backend) -> Optional[FlashBlockConfig]:
        e = self.get(flash_key(tq, tk, d, dtype, backend))
        if e is None:
            return None
        return FlashBlockConfig(bq=int(e["bq"]), bk=int(e["bk"]))

    def put_flash(self, tq: int, tk: int, d: int, dtype, backend,
                  cfg: FlashBlockConfig, **meta: Any) -> str:
        key = flash_key(tq, tk, d, dtype, backend)
        self.put(key, {"bq": cfg.bq, "bk": cfg.bk, "tuned_at": _now(), **meta})
        return key

    def get_flash_decode(self, tk: int, d: int, dtype,
                         backend) -> Optional[FlashBlockConfig]:
        e = self.get(flash_decode_key(tk, d, dtype, backend))
        if e is None:
            return None
        return FlashBlockConfig(bq=1, bk=int(e["bk"]))

    def put_flash_decode(self, tk: int, d: int, dtype, backend,
                         cfg: FlashBlockConfig, **meta: Any) -> str:
        key = flash_decode_key(tk, d, dtype, backend)
        self.put(key, {"bk": cfg.bk, "tuned_at": _now(), **meta})
        return key

    def get_flash_decode_paged(self, page_size: int, d: int, dtype,
                               backend) -> Optional[FlashBlockConfig]:
        e = self.get(flash_decode_paged_key(page_size, d, dtype, backend))
        if e is None:
            return None
        return FlashBlockConfig(bq=1, bk=int(e["bk"]))

    def put_flash_decode_paged(self, page_size: int, d: int, dtype, backend,
                               cfg: FlashBlockConfig, **meta: Any) -> str:
        key = flash_decode_paged_key(page_size, d, dtype, backend)
        self.put(key, {"bk": cfg.bk, "tuned_at": _now(), **meta})
        return key

    def get_ssd(self, chunk: int, p: int, n: int, dtype,
                backend) -> Optional[SSDBlockConfig]:
        e = self.get(ssd_key(chunk, p, n, dtype, backend))
        if e is None:
            return None
        return SSDBlockConfig(q=int(e["q"]), bp=int(e["bp"]))

    def put_ssd(self, chunk: int, p: int, n: int, dtype, backend,
                cfg: SSDBlockConfig, **meta: Any) -> str:
        key = ssd_key(chunk, p, n, dtype, backend)
        self.put(key, {"q": cfg.q, "bp": cfg.bp, "tuned_at": _now(), **meta})
        return key

    def get_flash_bwd(self, tq: int, tk: int, d: int, dtype,
                      backend) -> Optional[FlashBlockConfig]:
        e = self.get(flash_bwd_key(tq, tk, d, dtype, backend))
        if e is None:
            return None
        return FlashBlockConfig(bq=int(e["bq"]), bk=int(e["bk"]))

    def put_flash_bwd(self, tq: int, tk: int, d: int, dtype, backend,
                      cfg: FlashBlockConfig, **meta: Any) -> str:
        key = flash_bwd_key(tq, tk, d, dtype, backend)
        self.put(key, {"bq": cfg.bq, "bk": cfg.bk, "tuned_at": _now(), **meta})
        return key


def _now() -> str:
    return datetime.datetime.now().isoformat(timespec="seconds")


# --- process-global cache (what the `tuned` backend consults) ------------
_global: TuningCache | None = None
_global_lock = threading.Lock()


def get_cache(refresh: bool = False) -> TuningCache:
    """The shared cache instance, loaded lazily from default_cache_path().
    Re-resolved if REPRO_TUNING_CACHE changed since the last call, so
    tests and multi-experiment drivers can repoint it."""
    global _global
    with _global_lock:
        if _global is None or refresh or _global.path != default_cache_path():
            _global = TuningCache().load()
        return _global


def set_cache(cache: TuningCache | None) -> None:
    global _global
    with _global_lock:
        _global = cache


def reset_cache() -> None:
    set_cache(None)
