"""The timing harness shared by the benchmark suites and the autotuner.

Canonical home of `time_jax` (benchmarks/common.py re-exports it): the
autotuner must score candidate tile configs with exactly the clock the
benchmark tables are built from, or tuned-vs-default speedup claims
would compare two different measurement disciplines.
"""

from __future__ import annotations

import time

import jax


def time_jax(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call of a jax function."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
