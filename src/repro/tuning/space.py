"""Candidate tile spaces for the autotuner.

The static chooser in core.blocking picks ONE config from the paper's
VMEM-budget argument; this module enumerates the feasible neighbourhood
around it so the autotuner can let the hardware vote. Constraints are
the same as the chooser's (MXU/lane alignment, double-buffered VMEM
fit) — the sweep only reorders configs the analysis already admits.
"""

from __future__ import annotations

from repro.core import blocking, hw
from repro.core.blocking import BlockConfig, FlashBlockConfig, SSDBlockConfig

_BM = (128, 256, 512)
_BN = (128, 256, 512)
_BK = (128, 256, 512, 1024, 2048)
_BQ = (128, 256, 512)
_FBK = (128, 256, 512, 1024)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def matmul_candidates(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
    n_rhs: int = 1,
) -> list[BlockConfig]:
    """Feasible (bm, bn, bk) tiles for an (m, k) x (k, n) GEMM.

    The static default comes first so a tuner that times the list in
    order always has the fallback as its baseline. Tile dims larger than
    the (padded) problem are clamped, which collapses many grid points —
    duplicates are dropped.

    n_rhs=2 sizes the space for the fused dual-GEMM (gated) kernel:
    double B-side tiles and accumulators shrink the feasible set, and
    the default comes from the n_rhs-aware static chooser.
    """
    budget = int(chip.vmem_bytes * vmem_fraction)
    sub = chip.sublane(itemsize)
    lane = chip.lane

    default = blocking.choose_block_config(
        m, n, k, itemsize, chip=chip, vmem_fraction=vmem_fraction,
        n_rhs=n_rhs)
    out = [default]
    seen = {(default.bm, default.bn, default.bk)}
    for bm in _BM:
        bm = min(bm, _round_up(m, sub))
        for bn in _BN:
            bn = min(bn, _round_up(n, lane))
            for bk in _BK:
                bk = min(bk, _round_up(k, lane))
                cfg = BlockConfig(bm, bn, bk)
                key = (bm, bn, bk)
                if key in seen or \
                        cfg.vmem_bytes(itemsize, n_rhs=n_rhs) > budget:
                    continue
                seen.add(key)
                out.append(cfg)
    if max_candidates is not None:
        # Keep the default plus the highest-AI survivors: AI is the
        # paper's own proxy for which tiles can be compute-bound.
        rest = sorted(out[1:],
                      key=lambda c: -c.arithmetic_intensity(itemsize, n_rhs))
        out = out[:1] + rest[:max(0, max_candidates - 1)]
    return out


def gated_matmul_candidates(
    m: int,
    n: int,
    k: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[BlockConfig]:
    """Feasible tiles for the dual-GEMM SwiGLU kernel ((m, k) staged
    against two (k, n) operands) — matmul_candidates with n_rhs=2."""
    return matmul_candidates(m, n, k, itemsize, chip=chip,
                             vmem_fraction=vmem_fraction,
                             max_candidates=max_candidates, n_rhs=2)


def flash_candidates(
    tq: int,
    tk: int,
    d: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[FlashBlockConfig]:
    """Feasible (bq, bk) tiles for flash attention. The kernel requires
    block sizes to divide the (padded) sequence lengths, so candidates
    are filtered to divisors after clamping."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    default = blocking.choose_flash_config(tq, tk, d, itemsize, chip=chip)
    out = [default]
    seen = {(default.bq, default.bk)}
    for bq in _BQ:
        bq = min(bq, tq)
        if tq % bq:
            continue
        for bk in _FBK:
            bk = min(bk, tk)
            if tk % bk:
                continue
            cfg = FlashBlockConfig(bq, bk)
            if (bq, bk) in seen or cfg.vmem_bytes(d, itemsize) > budget:
                continue
            seen.add((bq, bk))
            out.append(cfg)
    if max_candidates is not None:
        out = out[:max(1, max_candidates)]
    return out


def flash_decode_candidates(
    tk: int,
    d: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[FlashBlockConfig]:
    """Feasible K/V tiles for the q_len=1 decode kernel. bq is pinned to
    1 by construction, so the space is one-dimensional: bk divisors of
    the cache depth. Larger bk deepens the DMA pipeline but coarsens the
    prefix skip (a near-empty cache still streams one full block), which
    is exactly the trade the timer should settle."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    default = blocking.choose_decode_config(tk, d, itemsize, chip=chip)
    out = [default]
    seen = {default.bk}
    for bk in _FBK:
        bk = min(bk, tk)
        if tk % bk or bk in seen:
            continue
        cfg = FlashBlockConfig(1, bk)
        if cfg.vmem_bytes(d, itemsize) > budget:
            continue
        seen.add(bk)
        out.append(cfg)
    if max_candidates is not None:
        out = out[:max(1, max_candidates)]
    return out


def flash_decode_paged_candidates(
    page_size: int,
    d: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[FlashBlockConfig]:
    """Feasible K/V tiles for the PAGED decode kernel, keyed by
    (page_size, head_dim): the grid streams one pool page per step, so
    bk must divide the page — the space is the divisor lattice of
    page_size, not of the cache depth. The whole-page default comes
    first (fewest grid steps per page); smaller sub-tiles trade grid
    overhead for a finer prefix skip on the slot's final page."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    default = FlashBlockConfig(1, page_size)
    out = [default]
    seen = {page_size}
    for bk in sorted({min(b, page_size) for b in (16, 32, 64) + _FBK},
                     reverse=True):
        if page_size % bk or bk in seen:
            continue
        cfg = FlashBlockConfig(1, bk)
        if cfg.vmem_bytes(d, itemsize) > budget:
            continue
        seen.add(bk)
        out.append(cfg)
    if max_candidates is not None:
        out = out[:max(1, max_candidates)]
    return out


def _halving_divisors(x: int, floor: int) -> list[int]:
    out = [x]
    while x % 2 == 0 and x // 2 >= floor:
        x //= 2
        out.append(x)
    return out


def ssd_candidates(
    chunk: int,
    p: int,
    n: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[SSDBlockConfig]:
    """Feasible (q, bp) execution tiles for the SSD intra-chunk kernel.

    Chunking is exact (DESIGN §6: the dual form is a blocked matmul
    along time), so the execution chunk q may be ANY divisor of the
    model chunk without changing the output — smaller q shrinks the
    quadratic (q, q) decay mask and CB score block quadratically at the
    cost of more inter-chunk scan steps; bp tiles the head dim P for
    VMEM headroom. The static chooser's pick comes first as the
    baseline; the rest is the halving-divisor lattice under the
    double-buffered VMEM budget."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    default = blocking.choose_ssd_config(
        chunk, p, n, itemsize, chip=chip, vmem_fraction=vmem_fraction)
    out = [default]
    seen = {(default.q, default.bp)}
    for q in _halving_divisors(chunk, 8):
        for bp in _halving_divisors(p, 8):
            cfg = SSDBlockConfig(q, bp)
            if (q, bp) in seen or cfg.vmem_bytes(n, itemsize) > budget:
                continue
            seen.add((q, bp))
            out.append(cfg)
    if max_candidates is not None:
        out = out[:max(1, max_candidates)]
    return out


def flash_bwd_candidates(
    tq: int,
    tk: int,
    d: int,
    itemsize: int,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    vmem_fraction: float = 0.5,
    max_candidates: int | None = None,
) -> list[FlashBlockConfig]:
    """Feasible (bq, bk) tiles for the two-sweep flash backward. Same
    divisor lattice as the forward, but the working set is heavier: the
    dK/dV sweep double-buffers q AND do tiles against the k/v residents
    and carries two f32 (bk, d) accumulators, so the VMEM filter adds
    those terms on top of the forward model."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    out = []
    seen = set()
    for cfg in flash_candidates(tq, tk, d, itemsize, chip=chip,
                                vmem_fraction=1.0):
        extra = (cfg.bq * d * itemsize * 2      # do stream, double-buffered
                 + 2 * cfg.bk * d * 4           # dk/dv f32 accumulators
                 + 4 * cfg.bq * 4)              # lse + delta rows
        if (cfg.bq, cfg.bk) in seen or \
                cfg.vmem_bytes(d, itemsize) + extra > budget:
            continue
        seen.add((cfg.bq, cfg.bk))
        out.append(cfg)
    if not out:
        out = [blocking.choose_flash_config(tq, tk, d, itemsize, chip=chip)]
    if max_candidates is not None:
        out = out[:max(1, max_candidates)]
    return out
