"""Benchmark-driven tile search.

The paper fixes one block size per experiment and shows blocking wins;
this module closes the loop: for a concrete (M, N, K, dtype, backend)
it times every feasible tile config (tuning.space) with the shared
timing harness (tuning.timing, also behind benchmarks/), and persists
the winner to the fingerprint-keyed cache (tuning.cache) that the
`tuned` backend in kernels/ops.py consults.

Entry points:
  tune_matmul / tune_flash_attention  — sweep one shape, cache winner
  warm_start                          — launcher hook: load the cache
      for a model config's hot GEMM shapes, optionally tuning misses
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import hw
from repro.core import policy as _pol
from repro.core import precision as _prec
from repro.core.blocking import BlockConfig, FlashBlockConfig
from repro.core.policy import Policy
from repro.kernels import ops as _ops
from repro.tuning import space as _space
from repro.tuning.cache import TuningCache, get_cache
from repro.tuning.timing import time_jax


def default_exec_policy() -> Policy:
    """The Pallas execution policy timings are valid for on this host:
    compiled on a real TPU, interpreter otherwise (interpret=None is
    exactly that auto rule). Interpret-mode timings exercise the full
    mechanism but are not TPU wall-clock — the cache-key fingerprint
    keeps the two populations apart."""
    return Policy(backend="pallas")


def default_exec_backend() -> str:
    """Deprecated string twin of default_exec_policy() (its
    kernel_fingerprint), kept for pre-Policy callers."""
    return default_exec_policy().kernel_fingerprint


def _exec_policy(policy, backend) -> Policy:
    """Explicit policy > deprecated backend string > this host's
    default execution policy."""
    if policy is None and backend is None:
        return default_exec_policy()
    return _pol.resolve(policy, backend)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    op: str                      # "matmul" | "flash"
    key: str                     # cache key the winner was stored under
    backend: str                 # policy.kernel_fingerprint the sweep ran on
    best: object                 # BlockConfig | FlashBlockConfig
    best_s: float
    baseline: object             # the static chooser's config
    baseline_s: float
    trials: tuple                # ((config, seconds), ...) in sweep order

    @property
    def speedup(self) -> float:
        return self.baseline_s / self.best_s if self.best_s > 0 else 1.0


def _timer(fn, args, interpret: bool, warmup: int, iters: int):
    # jit with the operands as real arguments — closing over them would
    # embed them as compile-time constants (one bloated recompile per
    # candidate, and XLA could fold parts of the graph it should time).
    if not interpret:
        fn = jax.jit(fn)
    return time_jax(fn, *args, warmup=warmup, iters=iters)


def _timing_meta(best_s: float, baseline_s: float) -> dict:
    """Advisory timing metadata, kept strictly JSON-finite: the static
    baseline config may itself have failed (inf) on this backend."""
    meta = {"time_us": round(best_s * 1e6, 2)}
    if math.isfinite(baseline_s) and best_s > 0:
        meta["baseline_us"] = round(baseline_s * 1e6, 2)
        meta["speedup"] = round(baseline_s / best_s, 4)
    return meta


def _sweep(op: str, desc: str, candidates, time_one, put_winner,
           cache: TuningCache, save: bool, backend: str) -> TuneResult:
    """Shared sweep skeleton for every tune_* entry point: time each
    candidate (an infeasible one scores inf and can never win), pick
    the winner against the static-chooser baseline (always candidate
    #0), persist it via put_winner, and package the TuneResult."""
    trials = []
    for cfg in candidates:
        try:
            t = time_one(cfg)
        except Exception:  # infeasible on this backend: never the winner
            t = float("inf")
        trials.append((cfg, t))

    baseline_cfg, baseline_s = trials[0]
    best_cfg, best_s = min(trials, key=lambda ct: ct[1])
    if not math.isfinite(best_s):
        raise RuntimeError(
            f"all {len(trials)} tile candidates failed for "
            f"{desc} on {backend}")
    key = put_winner(best_cfg, _timing_meta(best_s, baseline_s))
    if save:
        cache.save()
    return TuneResult(op, key, backend, best_cfg, best_s,
                      baseline_cfg, baseline_s, tuple(trials))


def tune_matmul(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    epilogue: str = "none",
    quant: str | None = None,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep tile configs for one GEMM shape and cache the winner.

    `epilogue` times the fused-flush variant (bias / bias_gelu /
    bias_silu / residual) with synthetic epilogue operands — the extra
    operand DMA and VPU work shift the optimum, so each variant gets
    its own cache entry (tuning.cache.matmul_key — keyed by the
    policy's kernel fingerprint).

    `quant` (None = the policy's quant field) selects the op: "int8"
    quantizes the weight operand and sweeps the matmul_q kernel — the
    1-byte weight stream shifts the optimum again, so winners land
    under the separate matmul_q key population (cache.matmul_q_key).
    Pass quant="off" with an int8 policy to tune the PLAIN kernel under
    that policy's fingerprint (the cotangent GEMMs of dense_q's
    backward run unquantized)."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    quant = pol.quant if quant is None else quant
    if quant not in _pol.QUANT_MODES:
        raise ValueError(f"unknown quant mode {quant!r}; expected one of "
                         f"{_pol.QUANT_MODES}")
    quantized = quant == "int8"
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    if np.dtype(dtype) == np.complex64:
        raise ValueError("tune the underlying real GEMMs (core.gemm "
                         "decomposes complex64 into 3 f32 GEMMs)")
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    itemsize = jnp.dtype(dtype).itemsize
    # epilogue operands ride the args tuple, NOT a closure: _timer jits
    # with real arguments so the operand DMA being tuned for is timed,
    # not constant-folded (see _timer's methodology note).
    args = (a, b) if not quantized else \
        (a,) + _prec.quantize_int8(b)
    ep_name = None
    if epilogue == "residual":
        ep_name = "residual"
        args += (jnp.asarray(rng.normal(size=(m, n)), dtype),)
    elif epilogue != "none":
        ep_name = "bias"
        args += (jnp.asarray(rng.normal(size=(n,)), dtype),)

    if quantized:
        time_one = lambda cfg: _timer(
            lambda x, w, s, *e, c=cfg: _ops.matmul_q(
                x, w, s, policy=pol, block=c, epilogue=epilogue,
                **({ep_name: e[0]} if ep_name else {})),
            args, interpret, warmup, iters)
        put_winner = lambda cfg, meta: cache.put_matmul_q(
            m, n, k, dtype, pol, cfg, epilogue=epilogue, **meta)
    else:
        time_one = lambda cfg: _timer(
            lambda x, y, *e, c=cfg: _ops.matmul(
                x, y, policy=pol, block=c, epilogue=epilogue,
                **({ep_name: e[0]} if ep_name else {})),
            args, interpret, warmup, iters)
        put_winner = lambda cfg, meta: cache.put_matmul(
            m, n, k, dtype, pol, cfg, epilogue=epilogue, **meta)

    op = "matmul_q" if quantized else "matmul"
    return _sweep(
        op,
        f"{op} {m}x{n}x{k} {np.dtype(dtype).name} epilogue={epilogue}",
        _space.matmul_candidates(m, n, k, itemsize, chip=chip,
                                 max_candidates=max_candidates),
        time_one, put_winner,
        cache, save, pol.kernel_fingerprint)


def tune_gated_matmul(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep tiles for the dual-GEMM SwiGLU kernel and cache the winner
    (the doubled B-side working set makes its optimum distinct from the
    plain GEMM's)."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    wg = jnp.asarray(rng.normal(size=(k, n)), dtype)
    wu = jnp.asarray(rng.normal(size=(k, n)), dtype)
    itemsize = jnp.dtype(dtype).itemsize

    return _sweep(
        "gated", f"gated {m}x{n}x{k} {np.dtype(dtype).name}",
        _space.gated_matmul_candidates(m, n, k, itemsize, chip=chip,
                                       max_candidates=max_candidates),
        lambda cfg: _timer(lambda x, g, u, c=cfg: _ops.gated_matmul(
            x, g, u, policy=pol, block=c),
            (a, wg, wu), interpret, warmup, iters),
        lambda cfg, meta: cache.put_gated(m, n, k, dtype, pol, cfg,
                                          **meta),
        cache, save, pol.kernel_fingerprint)


def tune_flash_attention(
    tq: int,
    tk: int,
    d: int,
    dtype="float32",
    *,
    heads: int = 1,
    causal: bool = True,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep (bq, bk) flash-attention tiles for one shape; cache winner."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, tq, heads, d)), dtype)
    kv = jnp.asarray(rng.normal(size=(1, tk, heads, d)), dtype)
    itemsize = jnp.dtype(dtype).itemsize

    return _sweep(
        "flash", f"flash {tq}x{tk}xd{d} {np.dtype(dtype).name}",
        _space.flash_candidates(tq, tk, d, itemsize, chip=chip,
                                max_candidates=max_candidates),
        lambda cfg: _timer(lambda x, y, c=cfg: _ops.flash_attention(
            x, y, y, causal=causal, policy=pol, block=c),
            (q, kv), interpret, warmup, iters),
        lambda cfg, meta: cache.put_flash(tq, tk, d, dtype, pol, cfg,
                                          **meta),
        cache, save, pol.kernel_fingerprint)


def tune_flash_decode(
    tk: int,
    d: int,
    dtype="float32",
    *,
    batch: int = 4,
    heads: int = 1,
    pos: int | None = None,
    window: int | None = None,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep K/V tile sizes for the q_len=1 decode kernel over a
    depth-tk cache and persist the winner under flash_decode_key.

    `pos` defaults to tk - 1 (a full cache): that is the worst case for
    DMA volume and the regime the steady-state serving loop lives in, so
    it is what the timer should optimise. The `batch` slots share one
    pos — per-slot raggedness moves block-skip work, not the optimum.
    """
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(batch, 1, heads, d)), dtype)
    kv = jnp.asarray(rng.normal(size=(batch, tk, heads, d)), dtype)
    pos_v = jnp.full((batch,), tk - 1 if pos is None else pos, jnp.int32)
    itemsize = jnp.dtype(dtype).itemsize

    return _sweep(
        "flash_decode", f"flash_decode {tk}xd{d} {np.dtype(dtype).name}",
        _space.flash_decode_candidates(tk, d, itemsize, chip=chip,
                                       max_candidates=max_candidates),
        lambda cfg: _timer(lambda x, y, p, c=cfg: _ops.flash_decode(
            x, y, y, pos=p, window=window, policy=pol, block=c),
            (q, kv, pos_v), interpret, warmup, iters),
        lambda cfg, meta: cache.put_flash_decode(tk, d, dtype, pol, cfg,
                                                 **meta),
        cache, save, pol.kernel_fingerprint)


def tune_flash_decode_paged(
    page_size: int,
    d: int,
    dtype="float32",
    *,
    batch: int = 4,
    heads: int = 1,
    pages_per_slot: int = 4,
    pos: int | None = None,
    window: int | None = None,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep sub-page K/V tiles for the paged decode kernel and persist
    the winner under flash_decode_paged_key — keyed by (page_size,
    head_dim), the only dims the tile space depends on (bk must divide
    the page; pool size and slot count just scale the grid).

    The synthetic pool maps slot b's pages identity-style (page b*pp+j)
    at full depth, the steady-state worst case. policy.quant_kv="int8"
    times the dequantizing variant: the int8 pool + scale planes are
    what streams, and the winner lands under the _kvint8-suffixed
    fingerprint so full-width winners are never served to it."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    pp = pages_per_slot
    n_pages = batch * pp
    depth = pp * page_size
    q = jnp.asarray(rng.normal(size=(batch, 1, heads, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(n_pages, page_size, heads, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(n_pages, page_size, heads, d)), dtype)
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(batch, pp)
    pos_v = jnp.full((batch,), depth - 1 if pos is None else pos, jnp.int32)
    ks = vs = None
    if pol.quant_kv == "int8":
        kp, ks = _prec.quantize_kv(kp)
        vp, vs = _prec.quantize_kv(vp)
        ks = ks.transpose(0, 2, 1)          # (P, Hkv, page_size)
        vs = vs.transpose(0, 2, 1)
    itemsize = 1 if pol.quant_kv == "int8" else jnp.dtype(dtype).itemsize

    return _sweep(
        "flash_decode_paged",
        f"flash_decode_paged p{page_size}xd{d} {np.dtype(dtype).name}",
        _space.flash_decode_paged_candidates(
            page_size, d, itemsize, chip=chip,
            max_candidates=max_candidates),
        lambda cfg: _timer(
            lambda x, kk, vv, t, p, c=cfg: _ops.flash_decode_paged(
                x, kk, vv, t, pos=p, window=window, ks=ks, vs=vs,
                policy=pol, block=c),
            (q, kp, vp, table, pos_v), interpret, warmup, iters),
        lambda cfg, meta: cache.put_flash_decode_paged(
            page_size, d, dtype, pol, cfg, **meta),
        cache, save, pol.kernel_fingerprint)


def tune_flash_bwd(
    tq: int,
    tk: int,
    d: int,
    dtype="float32",
    *,
    heads: int = 1,
    causal: bool = True,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep (bq, bk) for the two-sweep recompute backward and persist
    the winner under flash_bwd_key — a separate population from the
    forward's (the dK/dV accumulators + q/do re-streams shift the
    optimum). Residuals (o, lse) come from one un-timed forward call so
    the sweep times exactly what training's backward pass runs."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, tq, heads, d)), dtype)
    kv = jnp.asarray(rng.normal(size=(1, tk, heads, d)), dtype)
    do = jnp.asarray(rng.normal(size=(1, tq, heads, d)), dtype)
    o, lse = _ops.flash_attention_fwd(q, kv, kv, causal=causal, policy=pol)
    itemsize = jnp.dtype(dtype).itemsize

    return _sweep(
        "flash_bwd", f"flash_bwd {tq}x{tk}xd{d} {np.dtype(dtype).name}",
        _space.flash_bwd_candidates(tq, tk, d, itemsize, chip=chip,
                                    max_candidates=max_candidates),
        lambda cfg: _timer(
            lambda x, y, oo, g, l, c=cfg: _ops.flash_attention_bwd(
                x, y, y, oo, g, l, causal=causal, policy=pol, block=c),
            (q, kv, o, do, lse), interpret, warmup, iters),
        lambda cfg, meta: cache.put_flash_bwd(tq, tk, d, dtype, pol, cfg,
                                              **meta),
        cache, save, pol.kernel_fingerprint)


def tune_ssd(
    chunk: int,
    p: int,
    n: int,
    dtype="float32",
    *,
    heads: int = 4,
    groups: int = 1,
    batch: int = 1,
    seqlen: int | None = None,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    cache: TuningCache | None = None,
    chip: hw.ChipSpec | None = None,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep (q, bp) execution tiles for the SSD intra-chunk kernel
    over one (model chunk, head dim P, state dim N) shape and persist
    the winner under ssd_key.

    Because chunking is algebraically exact, every candidate computes
    the same output — the sweep is purely a perf vote between "bigger
    intra-chunk matmuls" (large q: quadratic (q, q) decay/score blocks,
    few scan steps) and "cheaper masks, longer scan" (small q). `seqlen`
    (default 4 model chunks) sets the timed sequence; decays are drawn
    negative, as mamba_apply's -exp(A_log)*dt always is."""
    pol = _exec_policy(policy, backend)
    if chip is not None:        # explicit kwarg overrides the policy's chip
        pol = pol.replace(chip=chip)
    chip = pol.chip
    cache = get_cache() if cache is None else cache
    interpret = pol.resolved_interpret
    rng = np.random.default_rng(seed)
    l = seqlen or 4 * chunk
    if l % chunk:
        raise ValueError(f"seqlen {l} must be a multiple of chunk {chunk}")
    x = jnp.asarray(rng.normal(size=(batch, l, heads, p)), dtype)
    a = jnp.asarray(-np.abs(rng.normal(size=(batch, l, heads))) * 0.1,
                    jnp.float32)
    b = jnp.asarray(rng.normal(size=(batch, l, groups, n)), dtype)
    c = jnp.asarray(rng.normal(size=(batch, l, groups, n)), dtype)
    itemsize = jnp.dtype(dtype).itemsize

    return _sweep(
        "ssd", f"ssd Q{chunk}xP{p}xN{n} {np.dtype(dtype).name}",
        _space.ssd_candidates(chunk, p, n, itemsize, chip=chip,
                              max_candidates=max_candidates),
        lambda cfg: _timer(lambda xx, aa, bb, cc, c=cfg: _ops.ssd(
            xx, aa, bb, cc, chunk, policy=pol, block=c),
            (x, a, b, c), interpret, warmup, iters),
        lambda cfg, meta: cache.put_ssd(chunk, p, n, dtype, pol, cfg,
                                        **meta),
        cache, save, pol.kernel_fingerprint)


def model_ssd_shapes(cfg, batch: int = 1, seq: int = 1) -> list[tuple]:
    """The SSD shapes a step of `cfg` routes through core.ssd, as
    deduplicated ``(op, chunk, P, N, "-")`` entries mirroring the other
    model_*_shapes 5-tuple layout. Both pure-SSM and hybrid families
    contribute (every mamba layer shares one shape); attention-only
    configs contribute nothing. `batch`/`seq` are accepted for signature
    symmetry — the SSD tile space depends only on (chunk, P, N)."""
    del batch, seq
    sc = getattr(cfg, "ssm", None)
    if sc is None or getattr(cfg, "family", None) not in ("ssm", "hybrid"):
        return []
    return [("ssd", sc.chunk, sc.head_dim, sc.d_state, "-")]


def model_attention_shapes(cfg, batch: int, seq: int,
                           backward: bool = False,
                           decode_len: int | None = None) -> list[tuple]:
    """The flash-kernel shapes a (batch, seq) step of `cfg` runs, as
    deduplicated ``(op, tq, tk, d, "-")`` entries — op "flash" (fused
    forward), "flash_bwd" (training backward, with backward=True) or
    "flash_decode" (``(op, 1, decode_len, d, "-")``, when a cache depth
    is given). Entries mirror model_gemm_shapes' 5-tuple layout so
    warm_start can interleave the two lists in one report.

    Attention shapes are per (batch x head) slice, so `batch` does not
    enter the keys — it is accepted for signature symmetry. Pure-SSM
    configs (no attention anywhere) contribute nothing."""
    del batch
    if getattr(cfg, "family", None) == "ssm" or \
            not getattr(cfg, "n_heads", 0):
        return []
    head_dim = getattr(cfg, "resolved_head_dim",
                       cfg.head_dim or cfg.d_model // cfg.n_heads)
    entries = set()
    if seq > 1:
        entries.add(("flash", seq, seq, head_dim, "-"))
        if backward:
            entries.add(("flash_bwd", seq, seq, head_dim, "-"))
    if decode_len:
        entries.add(("flash_decode", 1, decode_len, head_dim, "-"))
    return sorted(entries)


def model_gemm_shapes(cfg, batch: int, seq: int,
                      backward: bool = False,
                      quant: bool = False) -> list[tuple]:
    """The dense contractions a (batch, seq) step of `cfg` pushes
    through the core.gemm chokepoint, as deduplicated
    ``(op, m, n, k, epilogue)`` entries — op "matmul" (epilogue-variant
    GEMM), "matmul_q" (int8-weight GEMM) or "gated" (the dual-GEMM
    SwiGLU kernel, epilogue "-"). Covers attention projections, the FFN
    (fused: gated hidden + residual/bias down-projection, per cfg.mlp),
    and the logits GEMM at the PADDED vocab — the lm_head the model
    actually allocates.

    quant=True describes the model AFTER models.model.quantize_params:
    dense layers run matmul_q, gated layers decompose into two dense_q
    GEMMs of the hidden shape (models.layers.gated_apply), and —
    crucially — a TIED lm_head keeps running the PLAIN kernel (the
    embedding is in QUANT_EXCLUDE and embed_attend routes through
    gemm.matmul), so its entry stays op "matmul".

    backward=True adds the custom-VJP cotangent GEMMs per forward
    shape: da = g @ w.T is (m, k, n) and dw = x.T @ g is (k, n, m),
    plus the plain recompute GEMMs the fused paths' backward passes
    route through the chokepoint — without these, a tuned training run
    would only serve the forward third of its GEMM flops from the cache.
    dense_q's backward also differentiates through PLAIN matmuls (on
    the dequantized weights), so these stay op "matmul" under quant.
    """
    m = batch * seq
    head_dim = getattr(cfg, "resolved_head_dim",
                       cfg.head_dim or cfg.d_model // cfg.n_heads)
    vocab = getattr(cfg, "padded_vocab", cfg.vocab)
    qkv_ep = "bias" if getattr(cfg, "qkv_bias", False) else "none"
    dense_op = "matmul_q" if quant else "matmul"
    logits_op = "matmul" if getattr(cfg, "tie_embeddings", False) \
        else dense_op
    entries = {
        (dense_op, m, cfg.n_heads * head_dim, cfg.d_model, qkv_ep),    # Q
        (dense_op, m, cfg.n_kv_heads * head_dim, cfg.d_model, qkv_ep),  # K/V
        (dense_op, m, cfg.d_model, cfg.n_heads * head_dim, "none"),    # O
        (logits_op, m, vocab, cfg.d_model, "none"),                    # logits
    }
    if getattr(cfg, "mlp", "swiglu") == "swiglu":
        if quant:   # gated_apply decomposes into two dense_q GEMMs
            entries.add(("matmul_q", m, cfg.d_ff, cfg.d_model, "none"))
        else:
            entries.add(("gated", m, cfg.d_ff, cfg.d_model, "-"))
        entries.add((dense_op, m, cfg.d_model, cfg.d_ff, "residual"))
    else:  # gelu MLP: bias+act fused up, bias fused down (+residual xla)
        entries.add((dense_op, m, cfg.d_ff, cfg.d_model, "bias_gelu"))
        entries.add((dense_op, m, cfg.d_model, cfg.d_ff, "bias"))
    if backward:
        # fused backward passes recompute/differentiate through plain
        # GEMMs: each forward (m, n, k) contributes its unfused triple
        # and both cotangent triples, all epilogue-free.
        fwd = {(mm, nn, kk) for (_, mm, nn, kk, _) in entries}
        entries |= {("matmul", mm, nn, kk, "none")
                    for t in fwd
                    for (mm, nn, kk) in (t, (t[0], t[2], t[1]),
                                         (t[2], t[1], t[0]))}
    return sorted(entries)


def warm_start(
    cfg,
    batch: int,
    seq,
    *,
    policy: Policy | None = None,
    backend: str | None = None,         # deprecated string shim
    autotune: bool = False,
    backward: bool = False,
    decode_len: int | None = None,
    cache: TuningCache | None = None,
    iters: int = 2,
    max_candidates: int = 8,
) -> dict:
    """Launcher startup hook (launch/serve.py, launch/train.py).

    Loads the tuning cache and checks it for the model's hot GEMM
    shapes AND flash-attention shapes — `seq` may be an int or an
    iterable of sequence lengths (serving warms both the prefill rows
    batch*prompt_len and the decode rows batch*1); `decode_len` (the KV
    cache depth) adds the flash_decode shape, and backward=True adds
    both the cotangent GEMMs and the flash_bwd shape. With
    autotune=False this only reports coverage — misses fall back to the
    static chooser at run time, so serving never blocks on a sweep.
    With autotune=True the misses are tuned and persisted before the
    first step; a shape whose sweep fails outright is reported under
    "failed" and left to the fallback.

    `policy` is the execution policy whose kernel fingerprint keys the
    cache entries (launchers pass the policy they will run under;
    default: this host's execution policy).
    """
    pol = _exec_policy(policy, backend)
    cache = get_cache() if cache is None else cache
    dtype = getattr(cfg, "dtype", "float32")
    seqs = (seq,) if isinstance(seq, int) else tuple(seq)
    shapes = sorted({s for q in seqs
                     for s in model_gemm_shapes(cfg, batch, q,
                                                backward=backward,
                                                quant=pol.quant == "int8")}
                    | {s for q in seqs
                       for s in model_attention_shapes(
                           cfg, batch, q, backward=backward,
                           decode_len=decode_len)}
                    | set(model_ssd_shapes(cfg, batch)))
    hits, misses, tuned, failed = [], [], [], []
    for entry in shapes:
        op, m, n, k, ep = entry
        if op == "gated":
            hit = cache.get_gated(m, n, k, dtype, pol) is not None
        elif op == "matmul_q":
            hit = cache.get_matmul_q(m, n, k, dtype, pol,
                                     epilogue=ep) is not None
        elif op == "flash":
            hit = cache.get_flash(m, n, k, dtype, pol) is not None
        elif op == "flash_bwd":
            hit = cache.get_flash_bwd(m, n, k, dtype, pol) is not None
        elif op == "flash_decode":
            hit = cache.get_flash_decode(n, k, dtype, pol) is not None
        elif op == "ssd":
            hit = cache.get_ssd(m, n, k, dtype, pol) is not None
        else:
            hit = cache.get_matmul(m, n, k, dtype, pol,
                                   epilogue=ep) is not None
        if hit:
            hits.append(entry)
        elif autotune:
            try:
                if op == "gated":
                    tune_gated_matmul(m, n, k, dtype, policy=pol,
                                      cache=cache, iters=iters,
                                      max_candidates=max_candidates,
                                      save=False)
                elif op == "flash":
                    tune_flash_attention(m, n, k, dtype, policy=pol,
                                         cache=cache, iters=iters,
                                         max_candidates=max_candidates,
                                         save=False)
                elif op == "flash_bwd":
                    tune_flash_bwd(m, n, k, dtype, policy=pol,
                                   cache=cache, iters=iters,
                                   max_candidates=max_candidates,
                                   save=False)
                elif op == "flash_decode":
                    tune_flash_decode(n, k, dtype, policy=pol,
                                      cache=cache, iters=iters,
                                      max_candidates=max_candidates,
                                      save=False)
                elif op == "ssd":
                    tune_ssd(m, n, k, dtype, policy=pol,
                             cache=cache, iters=iters,
                             max_candidates=max_candidates,
                             save=False)
                else:
                    tune_matmul(m, n, k, dtype, epilogue=ep,
                                quant="int8" if op == "matmul_q" else "off",
                                policy=pol, cache=cache, iters=iters,
                                max_candidates=max_candidates, save=False)
                tuned.append(entry)
            except RuntimeError:  # every candidate failed: use fallback
                failed.append(entry)
        else:
            misses.append(entry)
    if tuned:
        cache.save()
    return {
        "path": cache.path,
        "fingerprint": cache.fingerprint,
        "backend": pol.kernel_fingerprint,
        "policy": pol.fingerprint(),
        "hits": hits,
        "misses": misses,
        "tuned": tuned,
        "failed": failed,
    }


def describe_warm_start(rep: dict) -> str:
    """One-line launcher log for a warm_start report."""
    line = (f"tuning cache {rep['path']} [{rep['backend']}]: "
            f"{len(rep['hits'])} hits, {len(rep['misses'])} misses, "
            f"{len(rep['tuned'])} tuned at startup")
    if rep.get("failed"):
        line += f", {len(rep['failed'])} failed (static fallback)"
    return line
