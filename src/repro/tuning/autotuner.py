"""Benchmark-driven tile search.

The paper fixes one block size per experiment and shows blocking wins;
this module closes the loop: for a concrete (M, N, K, dtype, backend)
it times every feasible tile config (tuning.space) with the shared
timing harness (tuning.timing, also behind benchmarks/), and persists
the winner to the fingerprint-keyed cache (tuning.cache) that the
`tuned` backend in kernels/ops.py consults.

Entry points:
  tune_matmul / tune_flash_attention  — sweep one shape, cache winner
  warm_start                          — launcher hook: load the cache
      for a model config's hot GEMM shapes, optionally tuning misses
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import hw
from repro.core.blocking import BlockConfig, FlashBlockConfig
from repro.kernels import ops as _ops
from repro.tuning import space as _space
from repro.tuning.cache import TuningCache, get_cache
from repro.tuning.timing import time_jax


def default_exec_backend() -> str:
    """The Pallas execution backend timings are valid for on this host:
    compiled on a real TPU, interpreter otherwise. Interpret-mode
    timings exercise the full mechanism but are not TPU wall-clock —
    the fingerprint keeps the two populations apart."""
    return "pallas" if jax.devices()[0].platform == "tpu" else "pallas_interpret"


@dataclasses.dataclass(frozen=True)
class TuneResult:
    op: str                      # "matmul" | "flash"
    key: str                     # cache key the winner was stored under
    backend: str
    best: object                 # BlockConfig | FlashBlockConfig
    best_s: float
    baseline: object             # the static chooser's config
    baseline_s: float
    trials: tuple                # ((config, seconds), ...) in sweep order

    @property
    def speedup(self) -> float:
        return self.baseline_s / self.best_s if self.best_s > 0 else 1.0


def _timer(fn, args, interpret: bool, warmup: int, iters: int):
    # jit with the operands as real arguments — closing over them would
    # embed them as compile-time constants (one bloated recompile per
    # candidate, and XLA could fold parts of the graph it should time).
    if not interpret:
        fn = jax.jit(fn)
    return time_jax(fn, *args, warmup=warmup, iters=iters)


def _timing_meta(best_s: float, baseline_s: float) -> dict:
    """Advisory timing metadata, kept strictly JSON-finite: the static
    baseline config may itself have failed (inf) on this backend."""
    meta = {"time_us": round(best_s * 1e6, 2)}
    if math.isfinite(baseline_s) and best_s > 0:
        meta["baseline_us"] = round(baseline_s * 1e6, 2)
        meta["speedup"] = round(baseline_s / best_s, 4)
    return meta


def tune_matmul(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    backend: str | None = None,
    cache: TuningCache | None = None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep tile configs for one GEMM shape and cache the winner."""
    backend = backend or default_exec_backend()
    cache = cache or get_cache()
    interpret = backend.endswith("interpret")
    rng = np.random.default_rng(seed)
    if np.dtype(dtype) == np.complex64:
        raise ValueError("tune the underlying real GEMMs (core.gemm "
                         "decomposes complex64 into 3 f32 GEMMs)")
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    itemsize = jnp.dtype(dtype).itemsize

    trials = []
    for cfg in _space.matmul_candidates(
            m, n, k, itemsize, chip=chip, max_candidates=max_candidates):
        try:
            t = _timer(lambda x, y, c=cfg: _ops.matmul(
                x, y, backend=backend, block=c, chip=chip),
                (a, b), interpret, warmup, iters)
        except Exception:  # infeasible on this backend: never the winner
            t = float("inf")
        trials.append((cfg, t))

    baseline_cfg, baseline_s = trials[0]     # static chooser is always first
    best_cfg, best_s = min(trials, key=lambda ct: ct[1])
    if not math.isfinite(best_s):
        raise RuntimeError(
            f"all {len(trials)} tile candidates failed for "
            f"matmul {m}x{n}x{k} {np.dtype(dtype).name} on {backend}")
    key = cache.put_matmul(m, n, k, dtype, backend, best_cfg,
                           **_timing_meta(best_s, baseline_s))
    if save:
        cache.save()
    return TuneResult("matmul", key, backend, best_cfg, best_s,
                      baseline_cfg, baseline_s, tuple(trials))


def tune_flash_attention(
    tq: int,
    tk: int,
    d: int,
    dtype="float32",
    *,
    heads: int = 1,
    causal: bool = True,
    backend: str | None = None,
    cache: TuningCache | None = None,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    warmup: int = 1,
    iters: int = 3,
    max_candidates: int | None = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep (bq, bk) flash-attention tiles for one shape; cache winner."""
    backend = backend or default_exec_backend()
    cache = cache or get_cache()
    interpret = backend.endswith("interpret")
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, tq, heads, d)), dtype)
    kv = jnp.asarray(rng.normal(size=(1, tk, heads, d)), dtype)
    itemsize = jnp.dtype(dtype).itemsize

    trials = []
    for cfg in _space.flash_candidates(
            tq, tk, d, itemsize, chip=chip, max_candidates=max_candidates):
        try:
            t = _timer(lambda x, y, c=cfg: _ops.flash_attention(
                x, y, y, causal=causal, backend=backend, block=c),
                (q, kv), interpret, warmup, iters)
        except Exception:
            t = float("inf")
        trials.append((cfg, t))

    baseline_cfg, baseline_s = trials[0]
    best_cfg, best_s = min(trials, key=lambda ct: ct[1])
    if not math.isfinite(best_s):
        raise RuntimeError(
            f"all {len(trials)} tile candidates failed for "
            f"flash {tq}x{tk}xd{d} {np.dtype(dtype).name} on {backend}")
    key = cache.put_flash(tq, tk, d, dtype, backend, best_cfg,
                          **_timing_meta(best_s, baseline_s))
    if save:
        cache.save()
    return TuneResult("flash", key, backend, best_cfg, best_s,
                      baseline_cfg, baseline_s, tuple(trials))


def model_gemm_shapes(cfg, batch: int, seq: int,
                      backward: bool = False) -> list[tuple[int, int, int]]:
    """The dense-contraction shapes a (batch, seq) step of `cfg` pushes
    through the core.gemm chokepoint: attention projections, FFN up /
    down, and the logits GEMM (at the PADDED vocab — the lm_head the
    model actually allocates). Deduplicated (m, n, k) triples.

    backward=True adds the custom-VJP cotangent GEMMs per forward
    shape: da = g @ w.T is (m, k, n) and dw = x.T @ g is (k, n, m) —
    without these, a tuned training run would only serve the forward
    third of its GEMM flops from the cache.
    """
    m = batch * seq
    head_dim = getattr(cfg, "resolved_head_dim",
                       cfg.head_dim or cfg.d_model // cfg.n_heads)
    vocab = getattr(cfg, "padded_vocab", cfg.vocab)
    shapes = {
        (m, cfg.n_heads * head_dim, cfg.d_model),          # Q proj
        (m, cfg.n_kv_heads * head_dim, cfg.d_model),       # K/V proj
        (m, cfg.d_model, cfg.n_heads * head_dim),          # O proj
        (m, cfg.d_ff, cfg.d_model),                        # FFN up/gate
        (m, cfg.d_model, cfg.d_ff),                        # FFN down
        (m, vocab, cfg.d_model),                           # logits
    }
    if backward:
        shapes |= {t for (mm, nn, kk) in tuple(shapes)
                   for t in ((mm, kk, nn), (kk, nn, mm))}
    return sorted(shapes)


def warm_start(
    cfg,
    batch: int,
    seq,
    *,
    backend: str | None = None,
    autotune: bool = False,
    backward: bool = False,
    cache: TuningCache | None = None,
    iters: int = 2,
    max_candidates: int = 8,
) -> dict:
    """Launcher startup hook (launch/serve.py, launch/train.py).

    Loads the tuning cache and checks it for the model's hot GEMM
    shapes — `seq` may be an int or an iterable of sequence lengths
    (serving warms both the prefill rows batch*prompt_len and the
    decode rows batch*1). With autotune=False this only reports
    coverage — misses fall back to the static chooser at run time, so
    serving never blocks on a sweep. With autotune=True the misses are
    tuned and persisted before the first step; a shape whose sweep
    fails outright is reported under "failed" and left to the fallback.
    """
    backend = backend or default_exec_backend()
    cache = cache or get_cache()
    dtype = getattr(cfg, "dtype", "float32")
    seqs = (seq,) if isinstance(seq, int) else tuple(seq)
    shapes = sorted({s for q in seqs
                     for s in model_gemm_shapes(cfg, batch, q,
                                                backward=backward)})
    hits, misses, tuned, failed = [], [], [], []
    for (m, n, k) in shapes:
        if cache.get_matmul(m, n, k, dtype, backend) is not None:
            hits.append((m, n, k))
        elif autotune:
            try:
                tune_matmul(m, n, k, dtype, backend=backend, cache=cache,
                            iters=iters, max_candidates=max_candidates,
                            save=False)
                tuned.append((m, n, k))
            except RuntimeError:  # every candidate failed: use fallback
                failed.append((m, n, k))
        else:
            misses.append((m, n, k))
    if tuned:
        cache.save()
    return {
        "path": cache.path,
        "fingerprint": cache.fingerprint,
        "backend": backend,
        "hits": hits,
        "misses": misses,
        "tuned": tuned,
        "failed": failed,
    }


def describe_warm_start(rep: dict) -> str:
    """One-line launcher log for a warm_start report."""
    line = (f"tuning cache {rep['path']} [{rep['backend']}]: "
            f"{len(rep['hits'])} hits, {len(rep['misses'])} misses, "
            f"{len(rep['tuned'])} tuned at startup")
    if rep.get("failed"):
        line += f", {len(rep['failed'])} failed (static fallback)"
    return line
